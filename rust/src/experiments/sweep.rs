//! `repro sweep`: run an experiment grid unattended, with provenance.
//!
//! A [`GridSpec`] expands into cells (one parameter map each); the
//! runner executes every cell as a **subprocess** (`repro sweep --cell
//! <spec>`) so a panicking cell — including the deliberate
//! `plant_fail` drill cells — costs one job, not the sweep. N worker
//! threads drain the job queue; by default the sweep aborts after the
//! first failure, `--continue-on-failure` finishes the grid either way.
//!
//! Results are content-addressed: the grid's canonical string hashes
//! (FNV-1a, shared with the bench gate) into the results directory
//! name, and each cell's canonical spec into its artifact file, so the
//! same grid always lands in the same place and identical seeded runs
//! are byte-identical. A `manifest.json` records every cell's hash,
//! status and parameters.
//!
//! The exit-code contract, for unattended drivers:
//!
//! * cell subprocess: `0` ok, anything else (panic = 101) failed;
//! * `repro sweep --grid`: exit `1` when any cell failed;
//! * `repro sweep diff`: exit `2` when a matched cell regressed past
//!   the gate threshold ([`crate::bench::gate::DEFAULT_THRESHOLD`]).
//!
//! `diff` accepts results directories (or their `manifest.json`) and
//! compares matched cells — job params + row labels + metric — through
//! [`crate::bench::gate::compare_cells`]; plain artifact files
//! (`BENCH_serve.json`, a cell artifact) diff the same way, which is
//! how CI gates the serve rows. Unmatched cells are reported, never
//! gated. `SWEEP_INJECT_REGRESSION=<factor>` multiplies the current
//! side's metrics — the CI drill proving the gate is armed.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::sync::{Arc, Mutex};

use super::harness;
use crate::bench::gate;
use crate::config::GridSpec;
use crate::error::{Error, Result};
use crate::util::json::{field_str, flat_objects};

/// Runner knobs (`-j`, `--continue-on-failure`, `--out`).
#[derive(Debug, Clone)]
pub struct SweepOptions {
    pub workers: usize,
    pub continue_on_failure: bool,
    /// Parent of the per-grid content-addressed directory.
    pub out_dir: String,
    /// Binary to spawn per cell; the current executable when `None`.
    pub repro_bin: Option<PathBuf>,
}

impl Default for SweepOptions {
    fn default() -> SweepOptions {
        SweepOptions {
            workers: 4,
            continue_on_failure: false,
            out_dir: "results".to_string(),
            repro_bin: None,
        }
    }
}

fn unknown_experiment(name: &str) -> Error {
    let known: Vec<&str> = harness::registry().iter().map(|e| e.name()).collect();
    Error::config(format!("unknown experiment `{name}` (have: {known:?})"))
}

/// Reject parameters the experiment does not declare — a typo'd grid
/// axis fails the whole sweep upfront instead of being ignored.
fn validate_params(exp: &dyn harness::Experiment, params: &harness::Params) -> Result<()> {
    for key in params.keys() {
        if !exp.param_schema().iter().any(|p| p.key == key) {
            let known: Vec<&str> = exp.param_schema().iter().map(|p| p.key).collect();
            return Err(Error::config(format!(
                "experiment `{}` has no parameter `{key}` (schema: {known:?})",
                exp.name()
            )));
        }
    }
    Ok(())
}

/// Run one grid cell in this process (`repro sweep --cell <spec>`): the
/// per-job subprocess entry point. The spec is space-separated `k=v`
/// pairs; `experiment=<name>` picks the experiment and `__plant_fail=1`
/// panics deliberately (the failure drill). `out` writes the cell's
/// provenance-stamped artifact.
pub fn run_cell(spec: &str, out: Option<&str>) -> Result<String> {
    let mut map = BTreeMap::new();
    for pair in spec.split_whitespace() {
        let (k, v) = pair
            .split_once('=')
            .ok_or_else(|| Error::config(format!("cell spec needs `k=v` pairs, got `{pair}`")))?;
        map.insert(k.to_string(), v.to_string());
    }
    if map.remove("__plant_fail").is_some() {
        panic!("sweep: planted cell failure (drill)");
    }
    let experiment = map.remove("experiment").unwrap_or_else(|| "memcmp".to_string());
    let exp = harness::lookup(&experiment).ok_or_else(|| unknown_experiment(&experiment))?;
    let mut params = harness::Params::new();
    for (k, v) in &map {
        params.set(k, v.clone());
    }
    validate_params(exp.as_ref(), &params)?;
    let run = exp.run(&params)?;
    let note = match out {
        Some(path) => {
            // The cell artifact's config is the full canonical spec
            // (params + experiment), matching the job hash the sweep
            // runner names the file by.
            let mut pairs: Vec<String> =
                params.pairs().map(|(k, v)| format!("{k}={v}")).collect();
            pairs.push(format!("experiment={experiment}"));
            pairs.sort();
            let artifact = harness::Artifact {
                bench: "sweep-cell".to_string(),
                mode: "cell".to_string(),
                machine: params.str_or("machine", "numa-4x4").to_string(),
                seed: params.get("seed").and_then(|s| s.parse().ok()),
                config: pairs.join(" "),
                extras: vec![
                    ("experiment".to_string(), format!("\"{experiment}\"")),
                    ("params".to_string(), format!("\"{}\"", params.canonical())),
                ],
                rows: run.rows.clone(),
            };
            std::fs::write(path, artifact.json())?;
            format!("\nwrote {path}")
        }
        None => String::new(),
    };
    Ok(format!("{}{note}", run.text))
}

/// Execute a grid: expand cells, spawn each as a subprocess across
/// `workers` threads, write per-cell artifacts and the sweep manifest
/// into `out_dir/<cfg-hash>/`. Returns the report, or
/// [`Error::Exit`] with code 1 when any cell failed.
pub fn run_sweep(grid: &GridSpec, opts: &SweepOptions) -> Result<String> {
    let exp = harness::lookup(&grid.experiment)
        .ok_or_else(|| unknown_experiment(&grid.experiment))?;
    // Fail fast on a typo'd axis before burning any cell runs.
    let mut probe = harness::Params::new();
    for (k, _) in &grid.axes {
        probe.set(k, "probe");
    }
    for (k, v) in &grid.extras {
        probe.set(k, v.clone());
    }
    validate_params(exp.as_ref(), &probe)?;

    let bin = match &opts.repro_bin {
        Some(p) => p.clone(),
        None => std::env::current_exe()
            .map_err(|e| Error::config(format!("cannot locate the repro binary: {e}")))?,
    };
    let jobs = grid.jobs();
    let n = jobs.len();
    let cfg_hash = gate::fnv1a(&grid.canonical());
    let dir = Path::new(&opts.out_dir).join(format!("{cfg_hash:016x}"));
    std::fs::create_dir_all(&dir)?;

    // One (spec, artifact path) per cell; the spec string is the cell's
    // canonical identity (sorted `k=v`, experiment included) and hashes
    // into its artifact name.
    let mut hashes = Vec::with_capacity(n);
    let mut work = Vec::with_capacity(n);
    for job in &jobs {
        let mut cell = job.clone();
        cell.insert("experiment".to_string(), grid.experiment.clone());
        let spec: Vec<String> = cell.iter().map(|(k, v)| format!("{k}={v}")).collect();
        let spec = spec.join(" ");
        let hash = gate::fnv1a(&spec);
        let file = dir.join(format!("{hash:016x}.json"));
        hashes.push(hash);
        work.push((spec, file.to_string_lossy().to_string()));
    }
    let work = Arc::new(work);
    // (next job index, abort flag) — fail-fast stops handing out jobs.
    let queue = Arc::new(Mutex::new((0usize, false)));
    let results = Arc::new(Mutex::new(vec![("skipped", 0i32); n]));
    let workers = opts.workers.clamp(1, n.max(1));
    let mut handles = Vec::with_capacity(workers);
    for _ in 0..workers {
        let work = Arc::clone(&work);
        let queue = Arc::clone(&queue);
        let results = Arc::clone(&results);
        let bin = bin.clone();
        let keep_going = opts.continue_on_failure;
        handles.push(std::thread::spawn(move || loop {
            let i = {
                let mut q = queue.lock().unwrap();
                if q.1 || q.0 >= n {
                    break;
                }
                q.0 += 1;
                q.0 - 1
            };
            let (spec, out_path) = &work[i];
            let status = Command::new(&bin)
                .args(["sweep", "--cell", spec, "--cell-out", out_path])
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .status();
            let (ok, code) = match status {
                Ok(s) if s.success() => (true, 0),
                Ok(s) => (false, s.code().unwrap_or(-1)),
                Err(_) => (false, -1),
            };
            results.lock().unwrap()[i] = (if ok { "ok" } else { "failed" }, code);
            if !ok && !keep_going {
                queue.lock().unwrap().1 = true;
            }
        }));
    }
    for h in handles {
        let _ = h.join();
    }

    let results = results.lock().unwrap();
    let (mut ok_n, mut failed_n, mut skipped_n) = (0usize, 0usize, 0usize);
    let mut job_lines = Vec::with_capacity(n);
    let mut report_lines = Vec::with_capacity(n);
    for (i, job) in jobs.iter().enumerate() {
        let (status, code) = results[i];
        match status {
            "ok" => ok_n += 1,
            "failed" => failed_n += 1,
            _ => skipped_n += 1,
        }
        let params: Vec<String> = job.iter().map(|(k, v)| format!("{k}={v}")).collect();
        let params = params.join(" ");
        let hash = hashes[i];
        job_lines.push(format!(
            "{{\"job_hash\":\"{hash:016x}\",\"status\":\"{status}\",\"artifact\":\"{hash:016x}.json\",\"params\":\"{params}\"}}"
        ));
        report_lines.push(match status {
            "ok" => format!("  ok      {hash:016x}  {params}"),
            "failed" => format!("  FAILED  {hash:016x}  {params} (exit {code})"),
            _ => format!("  skipped {hash:016x}  {params}"),
        });
    }
    // No timestamps anywhere: the manifest must be byte-identical for
    // identical seeded grids (pinned by the sweep determinism test).
    let manifest = format!(
        "{{\n  \"sweep\": \"{}\",\n  \"schema\": {},\n  \"git_rev\": \"{}\",\n  \"config_hash\": \"{cfg_hash:016x}\",\n  \"config\": \"{}\",\n  \"cells\": {n},\n  \"failed\": {failed_n},\n  \"jobs\": [{}]\n}}\n",
        grid.experiment,
        harness::SCHEMA_VERSION,
        gate::git_rev(),
        grid.canonical(),
        job_lines.join(",\n")
    );
    std::fs::write(dir.join("manifest.json"), &manifest)?;

    let skipped_note = if skipped_n > 0 {
        format!(", {skipped_n} skipped")
    } else {
        String::new()
    };
    let mut report = format!(
        "sweep `{}` on grid {cfg_hash:016x}: {n} cells, {ok_n} ok, {failed_n} failed{skipped_note}\n{}\nresults: {}\n",
        grid.experiment,
        report_lines.join("\n"),
        dir.display()
    );
    if failed_n > 0 {
        if skipped_n > 0 {
            report.push_str(
                "aborted after first failure (use --continue-on-failure to finish the grid)\n",
            );
        }
        return Err(Error::Exit { code: 1, report });
    }
    Ok(report)
}

/// Load gateable cells from a sweep run (results dir or its
/// `manifest.json`: job params + row labels + metric) or from a plain
/// artifact file (row labels + metric).
fn load_cells(path: &str) -> Result<Vec<(String, f64)>> {
    let p = Path::new(path);
    let manifest = if p.is_dir() { p.join("manifest.json") } else { p.to_path_buf() };
    let is_manifest =
        p.is_dir() || manifest.file_name().map(|f| f == "manifest.json").unwrap_or(false);
    let text = std::fs::read_to_string(&manifest)
        .map_err(|e| Error::config(format!("cannot read `{}`: {e}", manifest.display())))?;
    if !is_manifest {
        return Ok(gate::parse_cells(&text, gate::GATED_METRICS));
    }
    let dir = manifest.parent().map(Path::to_path_buf).unwrap_or_default();
    let mut out = Vec::new();
    for obj in flat_objects(&text) {
        if let (Some(status), Some(artifact), Some(params)) = (
            field_str(obj, "status"),
            field_str(obj, "artifact"),
            field_str(obj, "params"),
        ) {
            if status != "ok" {
                continue;
            }
            let cell_path = dir.join(&artifact);
            let cell_text = std::fs::read_to_string(&cell_path).map_err(|e| {
                Error::config(format!("cannot read cell `{}`: {e}", cell_path.display()))
            })?;
            for (k, v) in gate::parse_cells(&cell_text, gate::GATED_METRICS) {
                out.push((format!("{params} {k}"), v));
            }
        }
    }
    Ok(out)
}

/// `repro sweep diff <baseline> <current>`: gate two runs against each
/// other through the shared comparator. Passing runs return the report;
/// regressions return [`Error::Exit`] with code 2.
pub fn diff(baseline: &str, current: &str) -> Result<String> {
    let base = load_cells(baseline)?;
    let mut cur = load_cells(current)?;
    // The CI drill: multiply the current side to prove the gate trips.
    if let Ok(factor) = std::env::var("SWEEP_INJECT_REGRESSION") {
        if let Ok(factor) = factor.parse::<f64>() {
            for (_, v) in &mut cur {
                *v *= factor;
            }
        }
    }
    let report = gate::compare_cells(&base, &cur, gate::DEFAULT_THRESHOLD);
    let text = format!(
        "sweep diff: {} matched cells, {} regressed ({} only in current, {} only in baseline)\n{}",
        report.deltas.len(),
        report.regressions().len(),
        report.unmatched_current.len(),
        report.unmatched_baseline.len(),
        report.render()
    );
    if report.passed() {
        Ok(format!("{text}gate: OK\n"))
    } else {
        Err(Error::Exit { code: 2, report: format!("{text}gate: REGRESSED\n") })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CELL: &str = "experiment=memcmp machine=numa-2x2 scheds=afs engine=sim seed=3 smoke=true";

    #[test]
    fn run_cell_writes_a_provenance_stamped_artifact() {
        let path = std::env::temp_dir().join("bubbles-sweep-cell-unit.json");
        let out = run_cell(CELL, Some(&path.to_string_lossy())).unwrap();
        assert!(out.contains("afs"), "{out}");
        assert!(out.contains("wrote"), "{out}");
        let s = std::fs::read_to_string(&path).unwrap();
        crate::util::json::validate(&s).unwrap_or_else(|e| panic!("invalid JSON: {e}\n{s}"));
        assert!(s.contains("\"bench\": \"sweep-cell\""), "{s}");
        assert!(s.contains("\"config_hash\""), "{s}");
        assert!(s.contains("\"experiment\": \"memcmp\""), "{s}");
        assert!(s.contains("\"policy\":\"afs\""), "{s}");
    }

    #[test]
    #[should_panic(expected = "planted cell failure")]
    fn planted_cells_panic_deliberately() {
        let _ = run_cell("experiment=memcmp __plant_fail=1", None);
    }

    #[test]
    fn unknown_experiments_and_params_error_loudly() {
        let err = run_cell("experiment=warp", None).unwrap_err();
        assert!(err.to_string().contains("unknown experiment"), "{err}");
        let err = run_cell("experiment=memcmp warp=1", None).unwrap_err();
        assert!(err.to_string().contains("no parameter `warp`"), "{err}");
        assert!(err.to_string().contains("schema"), "{err}");
        let err = run_cell("experiment=memcmp notapair", None).unwrap_err();
        assert!(err.to_string().contains("k=v"), "{err}");
    }

    #[test]
    fn identical_cells_diff_clean_and_2x_trips() {
        // Two seeded sim cells with the same spec are bit-identical, so
        // their diff gates clean; a planted 2x makespan regresses.
        let dir = std::env::temp_dir();
        let a = dir.join("bubbles-sweep-diff-a.json");
        let b = dir.join("bubbles-sweep-diff-b.json");
        run_cell(CELL, Some(&a.to_string_lossy())).unwrap();
        run_cell(CELL, Some(&b.to_string_lossy())).unwrap();
        assert_eq!(
            std::fs::read(&a).unwrap(),
            std::fs::read(&b).unwrap(),
            "same seeded spec must produce byte-identical artifacts"
        );
        let out = diff(&a.to_string_lossy(), &b.to_string_lossy()).unwrap();
        assert!(out.contains("gate: OK"), "{out}");
        assert!(out.contains("0 regressed"), "{out}");
        // Doctor the current side: double one makespan.
        let doctored = std::fs::read_to_string(&b).unwrap();
        let (pre, rest) = doctored.split_once("\"makespan\":").unwrap();
        let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap();
        let span: u64 = rest[..end].parse().unwrap();
        let doctored = format!("{pre}\"makespan\":{}{}", span * 2, &rest[end..]);
        std::fs::write(&b, doctored).unwrap();
        match diff(&a.to_string_lossy(), &b.to_string_lossy()).unwrap_err() {
            Error::Exit { code, report } => {
                assert_eq!(code, 2, "regression exit contract");
                assert!(report.contains("REGRESSED"), "{report}");
            }
            other => panic!("want Exit, got {other}"),
        }
    }

    #[test]
    fn run_sweep_exit_contract_and_fail_fast() {
        // `false` as the cell binary: every cell fails without running
        // an experiment, which is exactly what the exit-code contract
        // and the fail-fast/continue-on-failure split need.
        let grid = GridSpec::from_toml(
            "[grid]\nexperiment = \"memcmp\"\nseed = [1, 2, 3]\n\
             [run]\nengine = \"sim\"\nsmoke = true\nmachine = \"smp-4\"\npolicy = \"afs\"",
        )
        .unwrap();
        let out_dir = std::env::temp_dir().join("bubbles-sweep-unit");
        let opts = SweepOptions {
            workers: 1,
            continue_on_failure: false,
            out_dir: out_dir.to_string_lossy().to_string(),
            repro_bin: Some(PathBuf::from("false")),
        };
        match run_sweep(&grid, &opts).unwrap_err() {
            Error::Exit { code, report } => {
                assert_eq!(code, 1, "failed sweep exit contract");
                assert!(report.contains("FAILED"), "{report}");
                assert!(report.contains("skipped"), "fail-fast must skip the rest: {report}");
                assert!(report.contains("--continue-on-failure"), "{report}");
            }
            other => panic!("want Exit, got {other}"),
        }
        match run_sweep(&grid, &SweepOptions { continue_on_failure: true, ..opts }).unwrap_err() {
            Error::Exit { code, report } => {
                assert_eq!(code, 1);
                assert!(report.contains("3 cells, 0 ok, 3 failed"), "{report}");
                assert!(!report.contains("skipped"), "{report}");
            }
            other => panic!("want Exit, got {other}"),
        }
        // The manifest exists and is valid JSON either way.
        let cfg = gate::fnv1a(&grid.canonical());
        let manifest = out_dir.join(format!("{cfg:016x}")).join("manifest.json");
        let s = std::fs::read_to_string(&manifest).unwrap();
        crate::util::json::validate(&s).unwrap_or_else(|e| panic!("invalid JSON: {e}\n{s}"));
        assert!(s.contains("\"config_hash\""), "{s}");
    }

    #[test]
    fn typoed_grid_axes_fail_before_any_cell_runs() {
        let grid =
            GridSpec::from_toml("[grid]\nexperiment = \"memcmp\"\nwarp = [1, 2]").unwrap();
        let err = run_sweep(&grid, &SweepOptions::default()).unwrap_err();
        assert!(err.to_string().contains("no parameter `warp`"), "{err}");
    }
}
