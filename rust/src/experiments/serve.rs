//! Multi-tenant serve harness (`repro serve`): drive a seeded bursty
//! job stream through both engines and report per-job fairness numbers.
//!
//! Each leg serves the *same* generated arrival stream under one
//! (engine, policy) pair and reports: mix makespan, admission-latency
//! quantiles, tail (p95/p99) **slowdown** versus a recorded solo-run
//! profile (each distinct job shape run alone on the same engine and
//! policy), admission throughput over the arrival span, and the mean
//! per-job local-touch ratio. The rows land in `BENCH_serve.json` so
//! bench-smoke can upload mix-level regressions, and the pinned tests
//! assert the tentpole claim: cross-job reallocation (`job-fair`) beats
//! the static per-tenant partition on mix makespan with bounded tail
//! slowdown.

use std::collections::HashMap;

use super::harness;
use crate::config::SchedKind;
use crate::error::{Error, Result};
use crate::serve::{
    quantile, run_native, run_sim, Arrival, GenConfig, JobApp, ServeConfig, ServeOutcome,
};
use crate::topology::Topology;
use crate::util::fmt::Table;

/// One (engine, policy) leg over the mix.
#[derive(Debug, Clone)]
pub struct ServeRow {
    pub engine: String,
    pub policy: String,
    pub jobs: usize,
    pub lost: usize,
    /// Whole-mix makespan (sim cycles / native wall ns).
    pub mix_makespan: u64,
    /// Admission latency (first dispatch − admission) quantiles.
    pub admission_p50: u64,
    pub admission_p99: u64,
    /// Tail slowdown vs the solo-run profile of each job's shape.
    pub p95_slowdown: f64,
    pub p99_slowdown: f64,
    /// Jobs admitted per second of engine time over the arrival span
    /// (sim cycles are counted as nanoseconds).
    pub admission_throughput: f64,
    pub mean_local_ratio: f64,
}

/// The serve comparison result.
#[derive(Debug, Clone)]
pub struct ServeCmp {
    pub title: String,
    pub rows: Vec<ServeRow>,
}

impl ServeCmp {
    /// Row accessor (panics on unknown leg — harness misuse).
    pub fn get(&self, engine: &str, policy: &str) -> &ServeRow {
        self.rows
            .iter()
            .find(|r| r.engine == engine && r.policy == policy)
            .expect("unknown (engine, policy) row")
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "engine",
            "policy",
            "jobs",
            "lost",
            "mix makespan (M)",
            "adm p50",
            "adm p99",
            "p95 slowdown",
            "p99 slowdown",
            "adm jobs/s",
            "local ratio",
        ]);
        for r in &self.rows {
            t.row(&[
                r.engine.clone(),
                r.policy.clone(),
                r.jobs.to_string(),
                r.lost.to_string(),
                format!("{:.2}", r.mix_makespan as f64 / 1e6),
                r.admission_p50.to_string(),
                r.admission_p99.to_string(),
                format!("{:.2}", r.p95_slowdown),
                format!("{:.2}", r.p99_slowdown),
                format!("{:.0}", r.admission_throughput),
                format!("{:.3}", r.mean_local_ratio),
            ]);
        }
        format!("== {} ==\n{}", self.title, t.render())
    }

    /// Structured harness rows for the `BENCH_serve.json` artifact and
    /// the sweep runner. `mix_makespan` and `p99_slowdown` are the
    /// gated metrics ([`crate::bench::gate::GATED_METRICS`]).
    pub fn harness_rows(&self) -> Vec<harness::Row> {
        self.rows
            .iter()
            .map(|r| {
                harness::Row::new()
                    .label("engine", r.engine.clone())
                    .label("policy", r.policy.clone())
                    .int("jobs", r.jobs as u64)
                    .int("lost", r.lost as u64)
                    .int("mix_makespan", r.mix_makespan)
                    .int("admission_p50", r.admission_p50)
                    .int("admission_p99", r.admission_p99)
                    .float("p95_slowdown", r.p95_slowdown)
                    .float("p99_slowdown", r.p99_slowdown)
                    .float("admission_throughput", r.admission_throughput)
                    .float("mean_local_ratio", r.mean_local_ratio)
            })
            .collect()
    }
}

/// The `serve` experiment on the shared harness: `repro serve` and
/// sweep grid cells both run through here. The `workload` param selects
/// the app shape the generator gives jobs (`touch` is the classic
/// region-touch job; `conduction`/`amr` emit real-app jobs; `mix`
/// sprinkles app jobs into the touch stream) so app shape is a grid
/// axis.
pub struct ServeExperiment;

const PARAMS: &[harness::ParamSpec] = &[
    harness::ParamSpec { key: "machine", help: "machine preset (default numa-4x4)" },
    harness::ParamSpec { key: "engine", help: "sim|native|both (default both)" },
    harness::ParamSpec { key: "workload", help: "touch|conduction|amr|mix (generated stream)" },
    harness::ParamSpec { key: "jobs", help: "generated stream length (default 200)" },
    harness::ParamSpec { key: "seed", help: "stream + engine seed" },
    harness::ParamSpec { key: "submitters", help: "native submitter threads (default 4)" },
    harness::ParamSpec { key: "queue", help: "serve a spool file instead of generating" },
    harness::ParamSpec { key: "gap", help: "inter-arrival gap for --queue streams" },
    harness::ParamSpec { key: "smoke", help: "CI stream: >= 1000 short jobs" },
    harness::ParamSpec { key: "trace", help: "write first-leg Chrome trace to this path" },
];

impl harness::Experiment for ServeExperiment {
    fn name(&self) -> &'static str {
        "serve"
    }

    fn param_schema(&self) -> &'static [harness::ParamSpec] {
        PARAMS
    }

    fn run(&self, args: &harness::Params) -> Result<harness::RunOutput> {
        let topo = args.machine()?;
        let smoke = args.flag("smoke");
        let seed = args.u64_or("seed", crate::sim::SimConfig::default().seed);
        let submitters = args.u64_or("submitters", 4).max(1) as usize;
        let trace_out = args.get("trace");
        let engines = match args.str_or("engine", "both") {
            "sim" => (true, false),
            "native" => (false, true),
            "both" => (true, true),
            other => {
                return Err(Error::config(format!(
                    "unknown engine `{other}` (want sim|native|both)"
                )))
            }
        };
        // The app shape the generated jobs carry (`touch` is the
        // classic region-touch member program).
        let (app, app_fraction) = match args.str_or("workload", "touch") {
            "touch" => (None, 0.0),
            "conduction" => (Some(JobApp::Conduction), 1.0),
            "amr" => (Some(JobApp::Amr), 1.0),
            "mix" => (None, 0.3),
            other => {
                return Err(Error::config(format!(
                    "unknown workload `{other}` (want touch|conduction|amr|mix)"
                )))
            }
        };
        // The stream: a spool file (`serve --queue`, fed by
        // `repro submit`) or the seeded bursty generator. `--smoke` is
        // the CI stream: the ISSUE-8 acceptance floor of >= 1000 short
        // jobs.
        let (arrivals, source) = match args.get("queue") {
            Some(path) => {
                if args.get("workload").is_some() {
                    return Err(Error::config(
                        "--workload applies to the generated stream (the spool \
                         carries each job's app)"
                            .to_string(),
                    ));
                }
                let specs = crate::serve::read_spool(path)?;
                if specs.is_empty() {
                    return Err(Error::config(format!("queue `{path}` holds no jobs")));
                }
                let gap = args.u64_or("gap", 10_000).max(1);
                let n = specs.len();
                let arrivals: Vec<_> =
                    specs.into_iter().map(|spec| Arrival { gap, spec }).collect();
                (arrivals, format!("queue {path} ({n} jobs)"))
            }
            None => {
                let gen = if smoke {
                    GenConfig { app, app_fraction, ..smoke_gen(seed) }
                } else {
                    GenConfig {
                        jobs: args.u64_or("jobs", 200).max(1) as usize,
                        seed,
                        app,
                        app_fraction,
                        ..GenConfig::default()
                    }
                };
                let arrivals = crate::serve::generate(&gen);
                (arrivals, format!("generated stream ({} jobs, seed {seed})", gen.jobs))
            }
        };
        let c = run(&topo, &arrivals, seed, engines, submitters, trace_out)?;
        let rows = c.harness_rows();
        let artifact = harness::Artifact {
            bench: "serve".to_string(),
            mode: if smoke { "smoke" } else { "full" }.to_string(),
            machine: topo.name().to_string(),
            seed: Some(seed),
            config: args.canonical(),
            extras: vec![("jobs".to_string(), arrivals.len().to_string())],
            rows: rows.clone(),
        };
        let trace_note = match trace_out {
            Some(p) => format!("\nwrote first-leg Chrome trace to {p}"),
            None => String::new(),
        };
        let text = format!("{}\nsource: {source}\n\n{}{}", c.title, c.render(), trace_note);
        Ok(harness::RunOutput {
            text,
            rows,
            artifact: Some(harness::ArtifactOut {
                path: "BENCH_serve.json".to_string(),
                artifact,
            }),
        })
    }
}

/// Solo-run profile: each distinct shape in the stream, run as the only
/// job on the same engine and policy. Keyed by [`crate::serve::JobSpec::shape_key`].
fn solo_profile(
    topo: &Topology,
    cfg: &ServeConfig,
    arrivals: &[Arrival],
    native: bool,
) -> Result<HashMap<String, u64>> {
    let mut out = HashMap::new();
    for a in arrivals {
        let key = a.spec.shape_key();
        if out.contains_key(&key) {
            continue;
        }
        let solo = [Arrival { gap: 1, spec: a.spec.clone() }];
        let o = if native {
            run_native(topo, cfg, &solo, 1, None)?
        } else {
            run_sim(topo, cfg, &solo, None)?
        };
        out.insert(key, o.jobs[0].makespan.max(1));
    }
    Ok(out)
}

/// Fold one leg's outcome + solo profile into a row.
fn row_of(engine: &str, out: &ServeOutcome, solo: &HashMap<String, u64>) -> ServeRow {
    let adm: Vec<f64> = out.jobs.iter().map(|j| j.admission_latency as f64).collect();
    let slow: Vec<f64> = out
        .jobs
        .iter()
        .map(|j| j.makespan as f64 / solo[&j.shape_key] as f64)
        .collect();
    let arrivals: Vec<u64> = out.jobs.iter().map(|j| j.arrived).collect();
    let span = arrivals.iter().max().unwrap_or(&0) - arrivals.iter().min().unwrap_or(&0);
    let local: Vec<f64> = out.jobs.iter().map(|j| j.local_ratio).collect();
    ServeRow {
        engine: engine.to_string(),
        policy: out.policy.clone(),
        jobs: out.jobs.len(),
        lost: out.lost,
        mix_makespan: out.mix_makespan,
        admission_p50: quantile(&adm, 0.5) as u64,
        admission_p99: quantile(&adm, 0.99) as u64,
        p95_slowdown: quantile(&slow, 0.95),
        p99_slowdown: quantile(&slow, 0.99),
        admission_throughput: out.jobs.len() as f64 / (span.max(1) as f64 / 1e9),
        mean_local_ratio: local.iter().sum::<f64>() / local.len().max(1) as f64,
    }
}

/// Serve one leg and compute its row (slowdowns vs that leg's own solo
/// profile). Returns the row and the raw outcome (tests want both).
pub fn run_leg(
    topo: &Topology,
    cfg: &ServeConfig,
    arrivals: &[Arrival],
    native: bool,
    submitters: usize,
    trace_out: Option<&str>,
) -> Result<(ServeRow, ServeOutcome)> {
    // Solo-profile runs happen first so the traced artifact holds only
    // the mix run's event stream.
    let solo = solo_profile(topo, cfg, arrivals, native)?;
    let out = if native {
        run_native(topo, cfg, arrivals, submitters, trace_out)?
    } else {
        run_sim(topo, cfg, arrivals, trace_out)?
    };
    let engine = if native { "native" } else { "sim" };
    Ok((row_of(engine, &out, &solo), out))
}

/// The standard comparison over one arrival stream. The sim legs are
/// `job-fair`, its static-partition baseline and the SS opportunist;
/// the native leg serves the same stream with `job-fair` through
/// `submitters` concurrent [`crate::exec::Submitter`] threads.
/// `engines` selects `(sim, native)`. `trace_out` writes the first
/// leg's mix-run event stream as Chrome trace-event JSON (one
/// representative timeline, as in `memcmp`).
pub fn run(
    topo: &Topology,
    arrivals: &[Arrival],
    seed: u64,
    engines: (bool, bool),
    submitters: usize,
    trace_out: Option<&str>,
) -> Result<ServeCmp> {
    let (sim, native) = engines;
    let mut rows = Vec::new();
    let mut trace_slot = trace_out;
    if sim {
        let sim_legs = [
            ServeConfig { kind: SchedKind::JobFair, static_partition: false, seed },
            ServeConfig { kind: SchedKind::JobFair, static_partition: true, seed },
            ServeConfig { kind: SchedKind::Ss, static_partition: false, seed },
        ];
        for cfg in &sim_legs {
            let (row, _) = run_leg(topo, cfg, arrivals, false, 1, trace_slot.take())?;
            rows.push(row);
        }
    }
    if native {
        let ncfg = ServeConfig { kind: SchedKind::JobFair, static_partition: false, seed };
        let (nrow, _) = run_leg(topo, &ncfg, arrivals, true, submitters, trace_slot.take())?;
        rows.push(nrow);
    }
    Ok(ServeCmp {
        title: format!("multi-tenant serve ({} jobs, {})", arrivals.len(), topo.name()),
        rows,
    })
}

/// The CI smoke stream: ≥1000 short jobs on the numa(4,4) preset.
pub fn smoke_gen(seed: u64) -> GenConfig {
    GenConfig { jobs: 1000, seed, mean_gap: 10_000, ..GenConfig::default() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::generate;

    fn quick_gen() -> GenConfig {
        GenConfig { jobs: 30, ..GenConfig::default() }
    }

    #[test]
    fn harness_reports_every_leg_with_zero_lost() {
        let topo = Topology::numa(2, 2);
        let gen = quick_gen();
        let c = run(&topo, &generate(&gen), gen.seed, (true, true), 2, None).unwrap();
        assert_eq!(c.rows.len(), 4, "3 sim legs + 1 native leg");
        for r in &c.rows {
            assert_eq!(r.lost, 0, "{}/{} lost jobs", r.engine, r.policy);
            assert_eq!(r.jobs, 30, "{}/{}", r.engine, r.policy);
            assert!(r.mix_makespan > 0);
            assert!(r.p99_slowdown >= r.p95_slowdown);
            assert!(r.admission_throughput > 0.0);
        }
        let out = c.render();
        assert!(out.contains("job-fair") && out.contains("job-fair-static"), "{out}");
        assert_eq!(c.harness_rows().len(), 4);
        for r in c.harness_rows() {
            let j = r.json();
            assert!(j.contains("\"p99_slowdown\""), "{j}");
        }
    }

    #[test]
    fn smoke_gen_is_at_least_a_thousand_jobs() {
        // ISSUE-8 acceptance: the --smoke stream drives >= 1000 jobs.
        assert!(smoke_gen(1).jobs >= 1000);
    }
}
