//! Ablations over the design choices DESIGN.md calls out.
//!
//! * **Bursting level** (§3.3.1): deep bursting favours affinity at the
//!   risk of imbalance; high bursting favours processor use.
//! * **Regeneration policy** (§3.3.3): none / idle-triggered /
//!   timeslice, on the AMR-like imbalanced workload.
//! * **Scheduler zoo**: every baseline on the Table-2 conduction
//!   workload (who sits where between Simple and Bound).

use std::sync::Arc;

use super::harness;
use crate::apps::amr::{self, AmrParams, SkewParams};
use crate::apps::conduction::{self, HeatParams};
use crate::apps::{engine_with, StructureMode};
use crate::config::SchedKind;
use crate::error::{Error, Result};
use crate::sched::factory::make_default;
use crate::sched::{BubbleConfig, BubbleScheduler};
use crate::sim::SimConfig;
use crate::task::BurstLevel;
use crate::topology::Topology;
use crate::util::fmt::Table;

/// (label, makespan) pair list.
#[derive(Debug, Clone)]
pub struct Ablation {
    pub title: String,
    pub rows: Vec<(String, u64)>,
}

impl Ablation {
    pub fn render(&self) -> String {
        let mut t = Table::new(&["variant", "makespan (Mcycles)"]);
        for (name, time) in &self.rows {
            t.row(&[name.clone(), format!("{:.2}", *time as f64 / 1e6)]);
        }
        format!("== {} ==\n{}", self.title, t.render())
    }

    pub fn get(&self, name: &str) -> u64 {
        self.rows.iter().find(|(n, _)| n == name).expect("row").1
    }

    /// Structured harness rows: one per variant, keyed by the sweep
    /// this ablation belongs to.
    pub fn harness_rows(&self, which: &str) -> Vec<harness::Row> {
        self.rows
            .iter()
            .map(|(name, time)| {
                harness::Row::new()
                    .label("ablation", which)
                    .label("variant", name.clone())
                    .int("makespan", *time)
            })
            .collect()
    }
}

/// The `ablations` experiment on the shared harness: `repro ablations`
/// and sweep grid cells both run through here. The `workload` param
/// selects the sweep (`--which` stays as the CLI spelling).
pub struct AblationsExperiment;

const PARAMS: &[harness::ParamSpec] = &[
    harness::ParamSpec { key: "machine", help: "machine preset (default numa-4x4)" },
    harness::ParamSpec { key: "workload", help: "burst|regen|zoo|memory|all (default all)" },
    harness::ParamSpec { key: "which", help: "alias of workload (CLI spelling)" },
];

impl harness::Experiment for AblationsExperiment {
    fn name(&self) -> &'static str {
        "ablations"
    }

    fn param_schema(&self) -> &'static [harness::ParamSpec] {
        PARAMS
    }

    fn run(&self, args: &harness::Params) -> Result<harness::RunOutput> {
        let topo = args.machine()?;
        let which = args.get("workload").or_else(|| args.get("which")).unwrap_or("all");
        let mut text = String::new();
        let mut rows = Vec::new();
        if which == "burst" || which == "all" {
            let a = burst_level(&topo, &HeatParams::conduction());
            rows.extend(a.harness_rows("burst"));
            text.push_str(&a.render());
            text.push('\n');
        }
        if which == "regen" || which == "all" {
            let a = regeneration_skewed(&topo, &SkewParams::default());
            rows.extend(a.harness_rows("regen-skew"));
            text.push_str(&a.render());
            text.push('\n');
            let a = regeneration(
                &topo,
                &AmrParams { cycles: 12, redraw_every: 3, ..Default::default() },
            );
            rows.extend(a.harness_rows("regen-amr"));
            text.push_str(&a.render());
            text.push('\n');
        }
        if which == "zoo" || which == "all" {
            let a = scheduler_zoo(&topo, &HeatParams::conduction());
            rows.extend(a.harness_rows("zoo"));
            text.push_str(&a.render());
            text.push('\n');
        }
        if which == "memory" || which == "all" {
            let a = memory_policy(&topo, &HeatParams::conduction());
            rows.extend(a.harness_rows("memory"));
            text.push_str(&a.render());
            text.push('\n');
        }
        if text.is_empty() {
            return Err(Error::config(format!("unknown ablation `{which}`")));
        }
        Ok(harness::RunOutput { text, rows, artifact: None })
    }
}

/// Bursting-level sweep on the balanced conduction workload.
pub fn burst_level(topo: &Topology, p: &HeatParams) -> Ablation {
    let mut rows = Vec::new();
    for (name, burst) in [
        ("immediate (machine list)", BurstLevel::Immediate),
        ("numa node", BurstLevel::Kind(crate::topology::LevelKind::NumaNode)),
        ("leaf (per-cpu)", BurstLevel::Leaf),
    ] {
        let sched = Arc::new(BubbleScheduler::new(BubbleConfig {
            default_burst: burst,
            ..BubbleConfig::default()
        }));
        let mut e = engine_with(topo, sched, SimConfig::default());
        conduction::build(&mut e, StructureMode::Bubbles, p);
        rows.push((name.to_string(), e.run().expect("run").total_time));
    }
    Ablation { title: "bursting level (conduction)".into(), rows }
}

/// Regeneration-policy sweep on the *terminal imbalance* workload
/// (§3.3.3: a light group finishes early, leaving its node idle).
pub fn regeneration_skewed(topo: &Topology, p: &amr::SkewParams) -> Ablation {
    let variants = regen_variants();
    let mut rows = Vec::new();
    for (name, cfg) in variants {
        let sched = Arc::new(BubbleScheduler::new(cfg));
        let mut e = engine_with(topo, sched, SimConfig::default());
        amr::build_skewed(&mut e, p);
        rows.push((name.to_string(), e.run().expect("run").total_time));
    }
    Ablation { title: "regeneration policy (terminal imbalance)".into(), rows }
}

fn regen_variants() -> Vec<(&'static str, BubbleConfig)> {
    vec![
        (
            "none (no rebalance)",
            BubbleConfig { idle_regen: false, thread_steal: false, ..BubbleConfig::default() },
        ),
        (
            "idle regeneration",
            BubbleConfig {
                idle_regen: true,
                thread_steal: false,
                regen_hysteresis: 200_000,
                ..BubbleConfig::default()
            },
        ),
        (
            "thread steal only",
            BubbleConfig {
                idle_regen: false,
                thread_steal: true,
                ..BubbleConfig::default()
            },
        ),
        (
            "idle + thread steal",
            BubbleConfig {
                idle_regen: true,
                thread_steal: true,
                regen_hysteresis: 5_000_000,
                ..BubbleConfig::default()
            },
        ),
        (
            "timeslice regeneration",
            BubbleConfig {
                idle_regen: false,
                thread_steal: false,
                default_timeslice: Some(3_000_000),
                ..BubbleConfig::default()
            },
        ),
    ]
}

/// Regeneration-policy sweep on the barrier-coupled AMR workload.
/// NB: the paper itself warns (§3.4) that preventive rebalancing "may
/// still have side effects and lead to pathological situations
/// (ping-ponging between tasks...)" — this sweep *measures* that: with
/// every cycle barrier-coupled, moving whole groups cannot beat the
/// per-cycle critical stripe, and regen churn shows up as overhead.
pub fn regeneration(topo: &Topology, p: &AmrParams) -> Ablation {
    let mut rows = Vec::new();
    for (name, cfg) in regen_variants() {
        let sched = Arc::new(BubbleScheduler::new(cfg));
        let mut e = engine_with(topo, sched, SimConfig::default());
        amr::build(&mut e, StructureMode::Bubbles, p);
        rows.push((name.to_string(), e.run().expect("run").total_time));
    }
    Ablation { title: "regeneration policy (AMR imbalance)".into(), rows }
}

/// Memory allocation policy (§2.3): first-touch is what lets the
/// affinity-preserving schedulers win; round-robin placement flattens
/// everyone towards the remote-access average.
pub fn memory_policy(topo: &Topology, p: &HeatParams) -> Ablation {
    use crate::sim::AllocPolicy;
    let mut rows = Vec::new();
    for (pname, policy) in
        [("first-touch", AllocPolicy::FirstTouch), ("round-robin", AllocPolicy::RoundRobin)]
    {
        for mode in [StructureMode::Bound, StructureMode::Bubbles, StructureMode::Simple] {
            let mut e = crate::apps::engine_for(topo, mode);
            conduction::build_with_policy(&mut e, mode, p, policy);
            let t = e.run().expect("run").total_time;
            rows.push((format!("{pname} / {}", mode.label()), t));
        }
    }
    Ablation { title: "memory allocation policy (conduction)".into(), rows }
}

/// Every scheduler on the conduction workload (full zoo).
pub fn scheduler_zoo(topo: &Topology, p: &HeatParams) -> Ablation {
    let mut rows = Vec::new();
    for kind in SchedKind::all() {
        if *kind == SchedKind::Gang {
            continue; // gang scheduling needs gangs, not loose stripes
        }
        let mode = match kind {
            SchedKind::Bubble => StructureMode::Bubbles,
            _ => StructureMode::Simple, // loose threads for baselines
        };
        let sched = match kind {
            SchedKind::Bubble => Arc::new(BubbleScheduler::new(BubbleConfig::default())) as _,
            _ => make_default(*kind),
        };
        let mut e = engine_with(topo, sched, SimConfig::default());
        conduction::build(&mut e, mode, p);
        rows.push((kind.label().to_string(), e.run().expect("run").total_time));
    }
    Ablation { title: "scheduler zoo (conduction)".into(), rows }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_heat() -> HeatParams {
        HeatParams { threads: 8, cycles: 5, work: 300_000, mem_fraction: 0.35 }
    }

    #[test]
    fn burst_level_deep_beats_immediate_on_balanced_load() {
        let topo = Topology::numa(2, 4);
        let a = burst_level(&topo, &small_heat());
        // Affinity (numa/leaf burst) must not lose to machine-level
        // scattering on a balanced workload.
        assert!(a.get("numa node") <= a.get("immediate (machine list)"));
    }

    #[test]
    fn regeneration_helps_terminal_imbalance() {
        // §3.3.3's own scenario: a heavy group outlives the others.
        // Rebalancing must clearly shorten the makespan.
        let topo = Topology::numa(4, 4);
        let p = amr::SkewParams::default();
        let a = regeneration_skewed(&topo, &p);
        let none = a.get("none (no rebalance)");
        let idle = a.get("idle + thread steal");
        assert!(
            (idle as f64) < none as f64 * 0.8,
            "rebalancing should clearly help: idle {idle} vs none {none}"
        );
    }

    #[test]
    fn regeneration_churn_is_bounded_on_coupled_cycles() {
        // The §3.4 caveat measured: on barrier-coupled AMR cycles,
        // rebalancing cannot beat the per-cycle critical stripe; it
        // must at worst cost bounded overhead, not collapse.
        let topo = Topology::numa(2, 2);
        let p = AmrParams { threads: 8, cycles: 8, redraw_every: 4, ..Default::default() };
        let a = regeneration(&topo, &p);
        let none = a.get("none (no rebalance)") as f64;
        let idle = a.get("idle regeneration") as f64;
        assert!(idle < none * 1.5, "regen churn exploded: {idle} vs {none}");
    }

    #[test]
    fn first_touch_beats_round_robin_for_affinity_schedulers() {
        let topo = Topology::numa(4, 4);
        let p = HeatParams { threads: 16, cycles: 6, work: 400_000, mem_fraction: 0.35 };
        let a = memory_policy(&topo, &p);
        // Bound with first-touch is all-local; with round-robin 3/4 of
        // its accesses are remote — it must get clearly slower.
        let ft = a.get("first-touch / Bound") as f64;
        let rr = a.get("round-robin / Bound") as f64;
        assert!(rr > ft * 1.2, "round-robin should hurt Bound: {rr} vs {ft}");
        // Simple barely cares: it was scattering anyway.
        let ft_s = a.get("first-touch / Simple") as f64;
        let rr_s = a.get("round-robin / Simple") as f64;
        let simple_delta = rr_s / ft_s;
        let bound_delta = rr / ft;
        assert!(
            simple_delta < bound_delta,
            "policy must matter less for Simple: {simple_delta} vs {bound_delta}"
        );
    }

    #[test]
    fn zoo_runs_every_scheduler() {
        let topo = Topology::numa(2, 2);
        let p = HeatParams { threads: 4, cycles: 3, work: 200_000, mem_fraction: 0.35 };
        let a = scheduler_zoo(&topo, &p);
        assert_eq!(a.rows.len(), SchedKind::all().len() - 1);
        assert!(a.rows.iter().all(|(_, t)| *t > 0));
    }
}
