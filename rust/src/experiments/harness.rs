//! Shared experiment harness: one `Experiment` trait, one `Row` shape,
//! one provenance-stamped artifact writer.
//!
//! Before this layer each experiment (`memcmp`, `adaptcmp`, `serve`,
//! the ablations) carried its own CLI glue, run loop and hand-rolled
//! JSON assembly. Now they all implement [`Experiment`]: a name, a
//! declared parameter schema, and `run(&Params) -> RunOutput` whose
//! [`Row`]s — string *labels* identifying the cell plus numeric
//! *metrics* — are what both the CLI artifact writer and the
//! `repro sweep` grid runner consume. Every artifact is stamped with
//! provenance ([`Artifact::json`]): schema version, git revision and
//! the FNV-1a config hash shared with the bench gate
//! ([`crate::bench::gate::fnv1a`]), so result history stays comparable
//! across runs, machines and commits.

use std::collections::{BTreeMap, HashMap};

use crate::bench::gate;
use crate::config::SchedKind;
use crate::error::{Error, Result};
use crate::topology::Topology;

/// Artifact schema version: bumped when the artifact envelope changes.
/// Version 3 added the provenance fields (`git_rev`, `config_hash`)
/// and the harness-rendered `results` rows.
pub const SCHEMA_VERSION: u32 = 3;

/// One declared parameter of an experiment: the key as it appears on
/// the CLI (`--key value`) and in sweep cells, plus a help line.
#[derive(Debug, Clone, Copy)]
pub struct ParamSpec {
    pub key: &'static str,
    pub help: &'static str,
}

/// Flat string parameters for one experiment run — the single currency
/// between the CLI (`--key value` options), the sweep runner (grid
/// axes) and the experiments themselves. Stored sorted so
/// [`Params::canonical`] is a stable hash input.
#[derive(Debug, Clone, Default)]
pub struct Params {
    map: BTreeMap<String, String>,
}

impl Params {
    pub fn new() -> Params {
        Params::default()
    }

    /// Adopt parsed CLI options verbatim.
    pub fn from_options(options: &HashMap<String, String>) -> Params {
        Params { map: options.iter().map(|(k, v)| (k.clone(), v.clone())).collect() }
    }

    pub fn set(&mut self, key: &str, value: impl Into<String>) {
        self.map.insert(key.to_string(), value.into());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(String::as_str)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Boolean flag: present and not explicitly disabled.
    pub fn flag(&self, key: &str) -> bool {
        self.map.get(key).map(|v| v != "false" && v != "0").unwrap_or(false)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.map.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.map.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Build the machine named by the `machine` param (`numa-4x4` when
    /// absent), with the error message every CLI test pins.
    pub fn machine(&self) -> Result<Topology> {
        let name = self.str_or("machine", "numa-4x4");
        Topology::preset(name).ok_or_else(|| {
            Error::config(format!(
                "unknown machine `{name}`; presets: {:?}",
                Topology::preset_names()
            ))
        })
    }

    /// Parse the comma-separated `scheds` param into policy kinds, or
    /// fall back to the experiment's default list.
    pub fn kinds(&self, default: Vec<SchedKind>) -> Result<Vec<SchedKind>> {
        match self.get("scheds") {
            Some(list) => list
                .split(',')
                .map(|s| {
                    SchedKind::parse(s.trim()).ok_or_else(|| {
                        Error::config(format!(
                            "unknown scheduler `{s}`; try `repro schedulers`"
                        ))
                    })
                })
                .collect(),
            None => Ok(default),
        }
    }

    /// Sorted `k=v` pairs joined by spaces: the canonical config string
    /// hashed into artifact provenance and sweep job identities.
    pub fn canonical(&self) -> String {
        let pairs: Vec<String> = self.map.iter().map(|(k, v)| format!("{k}={v}")).collect();
        pairs.join(" ")
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(String::as_str)
    }

    pub fn pairs(&self) -> impl Iterator<Item = (&str, &str)> {
        self.map.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }
}

/// One numeric result value. Integers render bare; floats render with
/// four decimals (enough for ratios, stable for bit-identical diffs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Metric {
    Int(u64),
    Float(f64),
}

impl Metric {
    pub fn as_f64(self) -> f64 {
        match self {
            Metric::Int(v) => v as f64,
            Metric::Float(v) => v,
        }
    }

    fn json(self) -> String {
        match self {
            Metric::Int(v) => v.to_string(),
            Metric::Float(v) => {
                if v.is_finite() {
                    format!("{v:.4}")
                } else {
                    "null".to_string()
                }
            }
        }
    }
}

/// One result row: string labels that identify the cell (policy,
/// structure, engine, workload, ...) plus numeric metrics. The JSON
/// rendering is flat, so [`crate::bench::gate::parse_cells`] can pull
/// the rows back out of any artifact for regression diffs.
#[derive(Debug, Clone, Default)]
pub struct Row {
    labels: Vec<(String, String)>,
    metrics: Vec<(String, Metric)>,
}

impl Row {
    pub fn new() -> Row {
        Row::default()
    }

    pub fn label(mut self, key: &str, value: impl Into<String>) -> Row {
        self.labels.push((key.to_string(), value.into()));
        self
    }

    pub fn int(mut self, key: &str, value: u64) -> Row {
        self.metrics.push((key.to_string(), Metric::Int(value)));
        self
    }

    pub fn float(mut self, key: &str, value: f64) -> Row {
        self.metrics.push((key.to_string(), Metric::Float(value)));
        self
    }

    pub fn get_label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    pub fn metric(&self, key: &str) -> Option<f64> {
        self.metrics.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_f64())
    }

    /// Stable cell identity: sorted `k=v` label pairs (the same key
    /// shape [`crate::bench::gate::parse_cells`] reconstructs).
    pub fn key(&self) -> String {
        let mut pairs: Vec<String> =
            self.labels.iter().map(|(k, v)| format!("{k}={v}")).collect();
        pairs.sort();
        pairs.join(" ")
    }

    /// Flat JSON object, labels first then metrics, insertion order.
    pub fn json(&self) -> String {
        let mut fields: Vec<String> =
            self.labels.iter().map(|(k, v)| format!("\"{k}\":\"{v}\"")).collect();
        fields.extend(self.metrics.iter().map(|(k, v)| format!("\"{k}\":{}", v.json())));
        format!("{{{}}}", fields.join(","))
    }
}

/// The provenance-stamped artifact envelope every experiment writes.
/// `extras` are pre-rendered JSON values (numbers, booleans, quoted
/// strings) appended verbatim after the common header fields.
#[derive(Debug, Clone, Default)]
pub struct Artifact {
    pub bench: String,
    pub mode: String,
    pub machine: String,
    pub seed: Option<u64>,
    /// Canonical config string; hashed (FNV-1a) into `config_hash`.
    pub config: String,
    pub extras: Vec<(String, String)>,
    pub rows: Vec<Row>,
}

impl Artifact {
    pub fn json(&self) -> String {
        let mut s = format!(
            "{{\n  \"bench\": \"{}\",\n  \"schema\": {},\n  \"git_rev\": \"{}\",\n  \"config_hash\": \"{:016x}\",\n  \"mode\": \"{}\",\n  \"machine\": \"{}\"",
            self.bench,
            SCHEMA_VERSION,
            gate::git_rev(),
            gate::fnv1a(&self.config),
            self.mode,
            self.machine
        );
        if let Some(seed) = self.seed {
            s.push_str(&format!(",\n  \"seed\": {seed}"));
        }
        for (k, v) in &self.extras {
            s.push_str(&format!(",\n  \"{k}\": {v}"));
        }
        let rows: Vec<String> = self.rows.iter().map(Row::json).collect();
        s.push_str(&format!(",\n  \"results\": [{}]\n}}\n", rows.join(",\n")));
        s
    }
}

/// An artifact plus the default path the CLI writes it to.
#[derive(Debug, Clone)]
pub struct ArtifactOut {
    pub path: String,
    pub artifact: Artifact,
}

/// What one experiment run produces: the human-readable report text,
/// the structured rows, and (for experiments that keep a `BENCH_*.json`
/// trail) the artifact. The CLI prints `text` and writes the artifact;
/// the sweep runner keeps only the rows and writes its own
/// content-addressed cell artifact.
#[derive(Debug, Clone, Default)]
pub struct RunOutput {
    pub text: String,
    pub rows: Vec<Row>,
    pub artifact: Option<ArtifactOut>,
}

/// A named, parameterised experiment the CLI and the sweep runner can
/// both drive.
pub trait Experiment {
    fn name(&self) -> &'static str;
    /// The parameters this experiment accepts — sweep cells are
    /// validated against this schema so a typo'd grid axis fails
    /// loudly instead of being ignored.
    fn param_schema(&self) -> &'static [ParamSpec];
    fn run(&self, p: &Params) -> Result<RunOutput>;
}

/// Every registered experiment, in listing order.
pub fn registry() -> Vec<Box<dyn Experiment>> {
    vec![
        Box::new(super::memcmp::MemCmpExperiment),
        Box::new(super::adaptcmp::AdaptCmpExperiment),
        Box::new(super::serve::ServeExperiment),
        Box::new(super::ablations::AblationsExperiment),
    ]
}

/// Look an experiment up by name.
pub fn lookup(name: &str) -> Option<Box<dyn Experiment>> {
    registry().into_iter().find(|e| e.name() == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_canonical_is_sorted_and_stable() {
        let mut p = Params::new();
        p.set("seed", "1");
        p.set("machine", "smp-4");
        p.set("scheds", "afs");
        assert_eq!(p.canonical(), "machine=smp-4 scheds=afs seed=1");
        let mut q = Params::new();
        q.set("scheds", "afs");
        q.set("machine", "smp-4");
        q.set("seed", "1");
        assert_eq!(p.canonical(), q.canonical(), "insertion order must not matter");
    }

    #[test]
    fn row_json_is_flat_and_cells_round_trip() {
        let row = Row::new()
            .label("engine", "sim")
            .label("policy", "afs")
            .int("makespan", 1200)
            .float("local_ratio", 0.75);
        let json = row.json();
        assert_eq!(
            json,
            r#"{"engine":"sim","policy":"afs","makespan":1200,"local_ratio":0.7500}"#
        );
        crate::util::json::validate(&json).unwrap();
        // The gate's generic cell extractor reconstructs the row key.
        let cells = gate::parse_cells(&json, &["makespan"]);
        assert_eq!(cells, vec![(format!("{}:makespan", row.key()), 1200.0)]);
    }

    #[test]
    fn artifact_json_carries_provenance() {
        let art = Artifact {
            bench: "memcmp".into(),
            mode: "smoke".into(),
            machine: "numa-2x2".into(),
            seed: Some(7),
            config: "machine=numa-2x2 seed=7".into(),
            extras: vec![("engine".into(), "\"sim\"".into()), ("cpus".into(), "4".into())],
            rows: vec![Row::new().label("policy", "afs").int("makespan", 10)],
        };
        let json = art.json();
        crate::util::json::validate(&json).unwrap_or_else(|e| panic!("invalid: {e}\n{json}"));
        assert!(json.contains("\"schema\": 3"), "{json}");
        assert!(json.contains("\"git_rev\""), "{json}");
        assert!(json.contains(&format!(
            "\"config_hash\": \"{:016x}\"",
            gate::fnv1a("machine=numa-2x2 seed=7")
        )));
        assert!(json.contains("\"seed\": 7"), "{json}");
        assert!(json.contains("\"cpus\": 4"), "{json}");
        assert!(json.contains("\"policy\":\"afs\""), "{json}");
    }

    #[test]
    fn registry_names_are_unique_and_looked_up() {
        let names: Vec<&str> = registry().iter().map(|e| e.name()).collect();
        assert_eq!(names, vec!["memcmp", "adaptcmp", "serve", "ablations"]);
        for n in names {
            assert!(lookup(n).is_some(), "{n} must resolve");
            let exp = lookup(n).unwrap();
            assert!(!exp.param_schema().is_empty(), "{n} must declare params");
        }
        assert!(lookup("warp").is_none());
    }
}
