//! Figure 5: fibonacci gain (%) from adding bubbles, vs thread count.
//!
//! Paper shape:
//! * (a) dual HT Pentium IV Xeon — performance *hurt* with only a few
//!   threads (bubble overhead), gain stabilising around 30–40 % from
//!   16 threads.
//! * (b) NUMA 4×4 Itanium II — 40 % at 32 threads, rising to ~80 % at
//!   512 threads.

use crate::apps::fib::{gain_percent, FibParams};
use crate::topology::Topology;
use crate::util::fmt::Table;

/// One sweep point.
#[derive(Debug, Clone)]
pub struct Point {
    pub threads: usize,
    pub gain_percent: f64,
}

/// A full Figure-5 series for one machine.
#[derive(Debug, Clone)]
pub struct Series {
    pub machine: String,
    pub points: Vec<Point>,
}

/// Default sweep (paper x-axis: 2 … 512 threads).
pub fn default_thread_counts() -> Vec<usize> {
    vec![2, 4, 8, 16, 32, 64, 128, 256, 512]
}

/// Run the sweep on one machine.
pub fn run(topo: &Topology, thread_counts: &[usize], p: &FibParams) -> Series {
    let points = thread_counts
        .iter()
        .map(|&n| Point { threads: n, gain_percent: gain_percent(topo, n, p) })
        .collect();
    Series { machine: topo.name().to_string(), points }
}

impl Series {
    /// Paper-style rendering (one row per thread count).
    pub fn render(&self) -> String {
        let mut t = Table::new(&["threads", "gain %"]);
        for pt in &self.points {
            t.row(&[pt.threads.to_string(), format!("{:+.1}", pt.gain_percent)]);
        }
        format!("machine: {}\n{}", self.machine, t.render())
    }

    /// Gain at (or nearest below) a thread count.
    pub fn gain_at(&self, threads: usize) -> f64 {
        self.points
            .iter()
            .filter(|p| p.threads <= threads)
            .next_back()
            .map(|p| p.gain_percent)
            .unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numa_series_shape() {
        // Figure 5(b) shape: gain grows with thread count and is
        // solidly positive once the machine is covered.
        let topo = Topology::numa(4, 4);
        let s = run(&topo, &[8, 64], &FibParams::default());
        assert!(s.gain_at(64) > s.gain_at(8) - 5.0, "gain should not collapse");
        assert!(s.gain_at(64) > 5.0, "gain at 64 threads: {}", s.gain_at(64));
    }

    #[test]
    fn render_lists_points() {
        let topo = Topology::numa(2, 2);
        let s = run(&topo, &[4, 8], &FibParams { total_leaf_work: 2_000_000, ..Default::default() });
        let out = s.render();
        assert!(out.contains("threads"));
        assert!(out.lines().count() >= 4);
    }
}
