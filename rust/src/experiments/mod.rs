//! Experiment drivers: one module per paper table/figure, shared by the
//! CLI (`repro <experiment>`), the bench targets, and the integration
//! tests. Each returns structured rows so tests can assert the *shape*
//! of the result (who wins, by what factor) and the CLI/bench print the
//! paper-style table.

pub mod ablations;
pub mod adaptcmp;
pub mod fig5;
pub mod harness;
pub mod memcmp;
pub mod serve;
pub mod sweep;
pub mod table1;
pub mod table2;
