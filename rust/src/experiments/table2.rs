//! Table 2: conduction & advection — Sequential / Simple / Bound /
//! Bubbles on the ccNUMA NovaScale stand-in.
//!
//! Paper numbers (16× Itanium II, 4 NUMA nodes):
//!
//! |            | Conduction time (s) | Speedup | Advection time (s) | Speedup |
//! |------------|---------------------|---------|--------------------|---------|
//! | Sequential | 250.2               |         | 16.13              |         |
//! | Simple     | 23.65               | 10.58   | 1.77               | 9.11    |
//! | Bound      | 15.82               | 15.82   | 1.30               | 12.40   |
//! | Bubbles    | 15.84               | 15.80   | 1.30               | 12.40   |
//!
//! Shape to reproduce: speedup(bubbles) ≈ speedup(bound) ≫
//! speedup(simple); advection speedups trail conduction's.

use crate::apps::conduction::{self, HeatParams};
use crate::apps::StructureMode;
use crate::topology::Topology;
use crate::util::fmt::Table;

/// One result row.
#[derive(Debug, Clone)]
pub struct Row {
    pub name: String,
    /// Simulated makespan (cycles).
    pub conduction: u64,
    pub advection: u64,
    pub conduction_speedup: f64,
    pub advection_speedup: f64,
}

/// Full Table-2 reproduction.
#[derive(Debug, Clone)]
pub struct Table2 {
    pub rows: Vec<Row>,
}

/// Run the experiment. `scale` shrinks cycle counts for fast CI runs
/// (1.0 = full).
pub fn run(topo: &Topology, scale: f64) -> Table2 {
    let scaled = |p: HeatParams| HeatParams {
        cycles: ((p.cycles as f64 * scale).round() as usize).max(2),
        ..p
    };
    let pc = scaled(HeatParams::conduction());
    let pa = scaled(HeatParams::advection());

    let seq_c = conduction::run_sequential(topo, &pc).total_time;
    let seq_a = conduction::run_sequential(topo, &pa).total_time;

    let mut rows = vec![Row {
        name: "Sequential".into(),
        conduction: seq_c,
        advection: seq_a,
        conduction_speedup: 1.0,
        advection_speedup: 1.0,
    }];
    for mode in [StructureMode::Simple, StructureMode::Bound, StructureMode::Bubbles] {
        let c = conduction::run(topo, mode, &pc).total_time;
        let a = conduction::run(topo, mode, &pa).total_time;
        rows.push(Row {
            name: mode.label().into(),
            conduction: c,
            advection: a,
            conduction_speedup: seq_c as f64 / c as f64,
            advection_speedup: seq_a as f64 / a as f64,
        });
    }
    Table2 { rows }
}

impl Table2 {
    /// Paper-style rendering.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "approach",
            "conduction (Mcycles)",
            "speedup",
            "advection (Mcycles)",
            "speedup",
        ]);
        for r in &self.rows {
            t.row(&[
                r.name.clone(),
                format!("{:.1}", r.conduction as f64 / 1e6),
                if r.name == "Sequential" { String::new() } else { format!("{:.2}", r.conduction_speedup) },
                format!("{:.2}", r.advection as f64 / 1e6),
                if r.name == "Sequential" { String::new() } else { format!("{:.2}", r.advection_speedup) },
            ]);
        }
        t.render()
    }

    /// Row accessor by name.
    pub fn row(&self, name: &str) -> &Row {
        self.rows.iter().find(|r| r.name == name).expect("row")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_paper() {
        let topo = Topology::numa(4, 4);
        let t2 = run(&topo, 0.2);
        let simple = t2.row("Simple");
        let bound = t2.row("Bound");
        let bubbles = t2.row("Bubbles");

        // Bound and Bubbles clearly beat Simple (paper: 15.8 vs 10.6).
        assert!(bound.conduction_speedup > simple.conduction_speedup * 1.2);
        assert!(bubbles.conduction_speedup > simple.conduction_speedup * 1.2);
        // Bubbles ≈ Bound (paper: 15.80 vs 15.82).
        let rel = (bubbles.conduction_speedup - bound.conduction_speedup).abs()
            / bound.conduction_speedup;
        assert!(rel < 0.12, "bubbles vs bound rel diff {rel}");
        // Advection speedups trail conduction's.
        assert!(bound.advection_speedup < bound.conduction_speedup);
        // Real parallel speedups on 16 CPUs.
        assert!(bound.conduction_speedup > 10.0);
        assert!(simple.conduction_speedup > 4.0);
    }

    #[test]
    fn render_contains_rows() {
        let topo = Topology::numa(2, 2);
        let t2 = run(&topo, 0.05);
        let s = t2.render();
        for name in ["Sequential", "Simple", "Bound", "Bubbles"] {
            assert!(s.contains(name), "{s}");
        }
    }
}
