//! Table 1: scheduler micro-costs — Yield (list search only) and
//! Switch (synchronisation + context switch).
//!
//! Paper numbers (2.66 GHz Pentium IV Xeon):
//!
//! |                   | Yield ns | Switch ns |
//! |-------------------|----------|-----------|
//! | Marcel (original) | 186      | 84        |
//! | Marcel bubbles    | 250      | 148       |
//! | NPTL (Linux 2.6)  | 672      | 1488      |
//!
//! Shape to reproduce: the bubble hierarchy search costs a constant
//! factor over a flat per-CPU list (paper: ×1.34 yield), and both are
//! far cheaper than kernel threads (NPTL's switch is ×10 Marcel's).
//!
//! Rows here:
//! * `flat`   — pick/stop over a 1-level machine (original Marcel's
//!   per-CPU list structure);
//! * `bubbles` — pick/stop over the deep Figure-2 machine with the full
//!   covering-chain search (bubble scheduler);
//! * `os-thread` — kernel-thread yield/switch via std::thread (the
//!   NPTL analogue on this testbed).

use std::sync::Arc;

use crate::bench::{black_box, Bench};
use crate::sched::{BubbleConfig, BubbleScheduler, Scheduler, StopReason, System};
use crate::task::PRIO_THREAD;
use crate::topology::{CpuId, Topology};
use crate::util::fmt::Table;

/// One measured row.
#[derive(Debug, Clone)]
pub struct Row {
    pub name: String,
    pub yield_ns: f64,
    pub switch_ns: f64,
}

/// The whole table.
#[derive(Debug, Clone)]
pub struct Table1 {
    pub rows: Vec<Row>,
}

/// Scheduler-level "Yield": one pick + one yield-stop round-trip on a
/// prepared system (the list search the paper times).
pub fn yield_roundtrip_ns(topo: Topology, threads: usize) -> f64 {
    let sys = Arc::new(System::new(Arc::new(topo)));
    let sched = BubbleScheduler::new(BubbleConfig {
        // Pure list costs: no rebalancing machinery on this path.
        idle_regen: false,
        thread_steal: false,
        ..BubbleConfig::default()
    });
    for i in 0..threads {
        let t = sys.tasks.new_thread(format!("y{i}"), PRIO_THREAD);
        sched.wake(&sys, t);
    }
    let cpu = CpuId(0);
    let mut b = Bench::new("internal").samples(15);
    let r = b.bench("yield", || {
        let t = sched.pick(&sys, cpu).expect("work");
        sched.stop(&sys, cpu, t, StopReason::Yield);
        black_box(t);
    });
    r.summary.median
}

/// User-level context-switch cost: two fibers ping-ponging on one OS
/// thread; one iteration = two stack switches (there and back), so the
/// per-switch cost is half the measured round trip.
pub fn fiber_switch_ns() -> f64 {
    use crate::exec::{yield_now, Fiber};
    let mut a = Fiber::new(|| loop {
        yield_now();
    });
    let mut bench = Bench::new("internal").samples(15);
    let r = bench.bench("fiber-roundtrip", || {
        black_box(a.resume());
    });
    // resume() + the fiber's yield = 2 switches.
    r.summary.median / 2.0
}

/// Kernel-thread context-switch cost: two OS threads ping-ponging over
/// a pair of channels (the NPTL-analogue "Switch" column: the paper's
/// 1488 ns were dominated by kernel synchronisation).
pub fn os_switch_ns() -> f64 {
    use std::sync::mpsc;
    let (tx_a, rx_a) = mpsc::channel::<()>();
    let (tx_b, rx_b) = mpsc::channel::<()>();
    let echo = std::thread::spawn(move || {
        while rx_a.recv().is_ok() {
            if tx_b.send(()).is_err() {
                break;
            }
        }
    });
    let mut bench = Bench::new("internal").samples(15);
    let r = bench.bench("os-roundtrip", || {
        tx_a.send(()).unwrap();
        rx_b.recv().unwrap();
    });
    drop(tx_a);
    let _ = echo.join();
    // One round trip = two kernel-mediated handoffs.
    r.summary.median / 2.0
}

/// OS-thread yield cost (the NPTL-analogue row).
pub fn os_yield_ns() -> f64 {
    let mut b = Bench::new("internal").samples(15);
    let r = b.bench("os-yield", || {
        std::thread::yield_now();
    });
    r.summary.median
}

/// Run the full Table-1 experiment. `switch_fn` supplies the measured
/// user-level context-switch cost (from the native executor; injected
/// to keep this module engine-agnostic). `os_switch_ns` likewise for
/// the kernel-thread switch (channel ping-pong).
pub fn run(user_switch_ns: f64, os_switch_ns: f64) -> Table1 {
    let flat_yield = yield_roundtrip_ns(Topology::smp(1), 4);
    let deep_yield = yield_roundtrip_ns(Topology::deep(), 4);
    Table1 {
        rows: vec![
            Row { name: "flat (marcel-original)".into(), yield_ns: flat_yield, switch_ns: user_switch_ns },
            Row { name: "hierarchy (marcel-bubbles)".into(), yield_ns: deep_yield, switch_ns: user_switch_ns },
            Row { name: "os-thread (nptl)".into(), yield_ns: os_yield_ns(), switch_ns: os_switch_ns },
        ],
    }
}

impl Table1 {
    pub fn render(&self) -> String {
        let mut t = Table::new(&["scheduler", "yield (ns)", "switch (ns)"]);
        for r in &self.rows {
            t.row(&[r.name.clone(), format!("{:.0}", r.yield_ns), format!("{:.0}", r.switch_ns)]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn yield_is_sub_microsecond_scale() {
        std::env::set_var("BENCH_FAST", "1");
        let ns = yield_roundtrip_ns(Topology::smp(1), 2);
        // Generous envelope: the paper's 250 ns was a 2.66 GHz Xeon;
        // we only assert the order of magnitude (list search, not ms).
        assert!(ns > 0.0 && ns < 50_000.0, "yield {ns} ns");
    }

    #[test]
    fn hierarchy_costs_more_than_flat_but_same_magnitude() {
        std::env::set_var("BENCH_FAST", "1");
        let flat = yield_roundtrip_ns(Topology::smp(1), 4);
        let deep = yield_roundtrip_ns(Topology::deep(), 4);
        // Paper: 250/186 = 1.34×. Allow noise but catch regressions
        // where the hierarchy search becomes O(machine) pathological.
        assert!(deep < flat * 20.0, "deep {deep} vs flat {flat}");
    }
}
