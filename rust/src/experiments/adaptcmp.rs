//! Adaptive-policy comparison harness: bursty and phase-changing load.
//!
//! The `adaptive` policy's claim is conditional: a *fixed* steal scope
//! only loses when the load shifts — machine-wide stealing (AFS)
//! scatters threads away from their data on every transient dip, while
//! scope-confined stealing idles CPUs when imbalance crosses the
//! boundary. So the harness measures exactly the shifting-load cases:
//!
//! * **phase-changing** ([`build_phases`]): barrier-coupled stripes
//!   whose heavy group rotates every phase (an AMR-style refinement
//!   front hopping around the mesh);
//! * **bursty** ([`build_bursts`]): a driver wakes waves of short
//!   threads with quiet gaps between, so the machine oscillates
//!   between oversubscribed and starved.
//!
//! `repro adaptcmp` prints the tables and drops `BENCH_adaptive.json`;
//! the tests pin the headline result (adaptive beats AFS on makespan
//! *and* locality on the phase-changing workload on numa(4,4)).

use std::sync::atomic::Ordering;

use super::harness;
use crate::apps::engine_with;
use crate::config::SchedKind;
use crate::error::{Error, Result};
use crate::sched::factory::make_default;
use crate::sim::{Program, SimConfig, SimEngine};
use crate::task::{TaskId, PRIO_THREAD};
use crate::topology::Topology;
use crate::util::fmt::Table;

/// Stripe bytes per thread (large enough that locality dominates).
const REGION_BYTES: u64 = 4 << 20;

/// Phase-changing workload parameters.
#[derive(Debug, Clone)]
pub struct PhaseParams {
    /// Stripes (oversubscribe the machine so rebalancing is real).
    pub threads: usize,
    /// Barrier phases; the hot group rotates every phase.
    pub phases: usize,
    /// Base compute per stripe per phase.
    pub work: u64,
    /// Hot-group multiplier.
    pub hot_factor: u64,
    /// Memory-bound fraction (the NUMA-sensitive part).
    pub mem_fraction: f64,
}

impl PhaseParams {
    /// The pinned comparison configuration for a machine.
    pub fn for_machine(topo: &Topology) -> PhaseParams {
        PhaseParams {
            threads: topo.n_cpus() + topo.n_cpus() / 2,
            phases: 12,
            work: 500_000,
            hot_factor: 3,
            mem_fraction: 0.5,
        }
    }

    /// CI smoke variant: same shape, far less work.
    pub fn smoke(topo: &Topology) -> PhaseParams {
        PhaseParams { phases: 4, work: 150_000, ..PhaseParams::for_machine(topo) }
    }
}

/// Bursty workload parameters.
#[derive(Debug, Clone)]
pub struct BurstParams {
    /// Waves of thread arrivals.
    pub waves: usize,
    /// Threads per wave.
    pub per_wave: usize,
    /// Compute per thread (split into `chunks` yield points).
    pub work: u64,
    pub chunks: usize,
    /// Driver compute between waves (the quiet gap).
    pub gap: u64,
    pub mem_fraction: f64,
}

impl BurstParams {
    pub fn for_machine(topo: &Topology) -> BurstParams {
        BurstParams {
            waves: 6,
            per_wave: topo.n_cpus(),
            work: 400_000,
            chunks: 4,
            gap: 600_000,
            mem_fraction: 0.4,
        }
    }

    pub fn smoke(topo: &Topology) -> BurstParams {
        BurstParams { waves: 3, work: 120_000, gap: 200_000, ..BurstParams::for_machine(topo) }
    }
}

/// Build the phase-changing stripes into an engine. Thread `i` belongs
/// to group `i % n_numa`; in phase `p` the group `p % n_numa` computes
/// `hot_factor`× the base work. Stripe data is first-touch homed.
pub fn build_phases(engine: &mut SimEngine, p: &PhaseParams) -> Vec<TaskId> {
    let n_groups = engine.sys.topo.n_numa().max(2);
    let barrier = engine.alloc_barrier(p.threads);
    let mut out = Vec::with_capacity(p.threads);
    for i in 0..p.threads {
        let r = engine.alloc_region_sized(REGION_BYTES, crate::sim::AllocPolicy::FirstTouch);
        let g = i % n_groups;
        let mut prog = Program::new();
        for ph in 0..p.phases {
            let w = if ph % n_groups == g { p.work * p.hot_factor } else { p.work };
            prog = prog.compute(w, p.mem_fraction, Some(r)).barrier(barrier);
        }
        let t = engine.add_thread(format!("phase{i}"), PRIO_THREAD, prog);
        engine.attach_region(t, r);
        engine.wake(t);
        out.push(t);
    }
    out
}

/// Build the bursty workload: a driver thread wakes `waves` batches of
/// workers with a compute gap between arrivals.
pub fn build_bursts(engine: &mut SimEngine, p: &BurstParams) -> Vec<TaskId> {
    let mut workers = Vec::with_capacity(p.waves * p.per_wave);
    for w in 0..p.waves {
        for i in 0..p.per_wave {
            let r =
                engine.alloc_region_sized(REGION_BYTES, crate::sim::AllocPolicy::FirstTouch);
            let mut prog = Program::new();
            let chunk = (p.work / p.chunks.max(1) as u64).max(1);
            for _ in 0..p.chunks.max(1) {
                prog = prog.compute(chunk, p.mem_fraction, Some(r));
            }
            let t = engine.add_thread(format!("w{w}b{i}"), PRIO_THREAD, prog);
            engine.attach_region(t, r);
            workers.push(t);
        }
    }
    let mut driver = Program::new();
    for w in 0..p.waves {
        driver = driver.compute(p.gap, 0.0, None);
        for i in 0..p.per_wave {
            driver = driver.wake(workers[w * p.per_wave + i]);
        }
    }
    let d = engine.add_thread("driver", PRIO_THREAD, driver);
    engine.wake(d);
    workers
}

/// One policy's behaviour on one workload.
#[derive(Debug, Clone)]
pub struct AdaptRow {
    pub sched: String,
    pub makespan: u64,
    pub local_ratio: f64,
    pub migrations: u64,
    pub cross_node: u64,
    pub steals: u64,
    pub scope_widens: u64,
    pub scope_narrows: u64,
}

/// The comparison result.
#[derive(Debug, Clone)]
pub struct AdaptCmp {
    pub title: String,
    pub rows: Vec<AdaptRow>,
}

impl AdaptCmp {
    /// Row accessor by policy name (panics on unknown name — harness
    /// misuse).
    pub fn get(&self, sched: &str) -> &AdaptRow {
        self.rows.iter().find(|r| r.sched == sched).expect("unknown policy row")
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "policy",
            "makespan (Mcycles)",
            "local ratio",
            "migrations",
            "cross-node",
            "steals",
            "widens",
            "narrows",
        ]);
        for r in &self.rows {
            t.row(&[
                r.sched.clone(),
                format!("{:.2}", r.makespan as f64 / 1e6),
                format!("{:.3}", r.local_ratio),
                r.migrations.to_string(),
                r.cross_node.to_string(),
                r.steals.to_string(),
                r.scope_widens.to_string(),
                r.scope_narrows.to_string(),
            ]);
        }
        format!("== {} ==\n{}", self.title, t.render())
    }

    /// Structured harness rows for the artifact trail and the sweep
    /// runner (`BENCH_adaptive.json`).
    pub fn harness_rows(&self, workload: &str) -> Vec<harness::Row> {
        self.rows
            .iter()
            .map(|r| {
                harness::Row::new()
                    .label("workload", workload)
                    .label("policy", r.sched.clone())
                    .int("makespan", r.makespan)
                    .float("local_ratio", r.local_ratio)
                    .int("migrations", r.migrations)
                    .int("cross_node", r.cross_node)
            })
            .collect()
    }
}

/// The `adaptcmp` experiment on the shared harness: `repro adaptcmp`
/// and sweep grid cells both run through here. The `workload` param
/// narrows the run to one of the two load shapes (grids sweep them as
/// an axis); the CLI default runs both, as it always has.
pub struct AdaptCmpExperiment;

const PARAMS: &[harness::ParamSpec] = &[
    harness::ParamSpec { key: "machine", help: "machine preset (default numa-4x4)" },
    harness::ParamSpec { key: "scheds", help: "comma-separated policy list" },
    harness::ParamSpec { key: "workload", help: "phase|bursty|both (default both)" },
    harness::ParamSpec { key: "seed", help: "sim engine seed" },
    harness::ParamSpec { key: "smoke", help: "small CI-sized run" },
    harness::ParamSpec { key: "trace", help: "write first-leg Chrome trace to this path" },
];

impl harness::Experiment for AdaptCmpExperiment {
    fn name(&self) -> &'static str {
        "adaptcmp"
    }

    fn param_schema(&self) -> &'static [harness::ParamSpec] {
        PARAMS
    }

    fn run(&self, args: &harness::Params) -> Result<harness::RunOutput> {
        let topo = args.machine()?;
        let kinds = args.kinds(default_kinds())?;
        let smoke = args.flag("smoke");
        let seed = args.u64_or("seed", SimConfig::default().seed);
        let (pp, bp) = if smoke {
            (PhaseParams::smoke(&topo), BurstParams::smoke(&topo))
        } else {
            (PhaseParams::for_machine(&topo), BurstParams::for_machine(&topo))
        };
        let trace_out = args.get("trace");
        let workload = args.str_or("workload", "both");
        let (want_phase, want_bursty) = match workload {
            "phase" => (true, false),
            "bursty" => (false, true),
            "both" => (true, true),
            other => {
                return Err(Error::config(format!(
                    "unknown workload `{other}` (want phase|bursty|both)"
                )))
            }
        };
        let mut rows = Vec::new();
        let mut tables = Vec::new();
        if want_phase {
            let phase = run_phase(&topo, &pp, &kinds, seed, trace_out);
            rows.extend(phase.harness_rows("phase"));
            tables.push(phase.render());
        }
        if want_bursty {
            let bursty = run_bursty(&topo, &bp, &kinds, seed);
            rows.extend(bursty.harness_rows("bursty"));
            tables.push(bursty.render());
        }
        let artifact = harness::Artifact {
            bench: "adaptcmp".to_string(),
            mode: if smoke { "smoke" } else { "full" }.to_string(),
            machine: topo.name().to_string(),
            seed: Some(seed),
            config: args.canonical(),
            extras: Vec::new(),
            rows: rows.clone(),
        };
        let trace_note = match trace_out {
            Some(p) => format!("\nwrote first-leg Chrome trace to {p}"),
            None => String::new(),
        };
        let text = format!(
            "adaptive steal-scope comparison on `{}`{}\n\n{}{}",
            topo.name(),
            if smoke { " (smoke)" } else { "" },
            tables.join("\n"),
            trace_note
        );
        Ok(harness::RunOutput {
            text,
            rows,
            artifact: Some(harness::ArtifactOut {
                path: "BENCH_adaptive.json".to_string(),
                artifact,
            }),
        })
    }
}

/// Policies compared by default: the adaptive policy against the
/// strongest fixed-scope opportunists and the memory-aware policy.
pub fn default_kinds() -> Vec<SchedKind> {
    vec![SchedKind::Adaptive, SchedKind::Afs, SchedKind::Lds, SchedKind::Cafs, SchedKind::Memaware]
}

fn collect(title: String, runs: Vec<(SchedKind, SimEngine, u64)>) -> AdaptCmp {
    let rows = runs
        .into_iter()
        .map(|(kind, e, makespan)| {
            let m = &e.sys.metrics;
            AdaptRow {
                sched: kind.label().to_string(),
                makespan,
                local_ratio: m.local_ratio(),
                migrations: m.migrations.load(Ordering::Relaxed),
                cross_node: m.cross_node_migrations.load(Ordering::Relaxed),
                steals: m.steals.load(Ordering::Relaxed),
                scope_widens: m.scope_widens.load(Ordering::Relaxed),
                scope_narrows: m.scope_narrows.load(Ordering::Relaxed),
            }
        })
        .collect();
    AdaptCmp { title, rows }
}

/// Run the phase-changing workload under each policy. `seed` drives
/// the engine's timing jitter: same seed, identical numbers.
/// `trace_out` writes the first policy leg's event stream as Chrome
/// trace-event JSON — the phase-changing workload is where the
/// adaptive policy's ScopeChange events are worth looking at.
pub fn run_phase(
    topo: &Topology,
    p: &PhaseParams,
    kinds: &[SchedKind],
    seed: u64,
    trace_out: Option<&str>,
) -> AdaptCmp {
    let mut runs = Vec::with_capacity(kinds.len());
    for (i, &kind) in kinds.iter().enumerate() {
        let cfg = SimConfig { seed, ..SimConfig::default() };
        let mut e = engine_with(topo, make_default(kind), cfg);
        let traced = i == 0 && trace_out.is_some();
        if traced {
            e.sys.trace.set_enabled(true);
        }
        build_phases(&mut e, p);
        let rep = e.run().expect("adaptcmp phase run");
        if traced {
            let path = trace_out.unwrap();
            let recs = e.sys.trace.drain();
            let label = format!("adaptcmp phase/{} on {}", kind.label(), topo.name());
            let json = crate::trace::export::chrome_json(&recs, topo.n_cpus(), &label);
            std::fs::write(path, json).unwrap_or_else(|err| panic!("write trace {path}: {err}"));
        }
        runs.push((kind, e, rep.total_time));
    }
    collect(
        format!(
            "phase-changing load ({} stripes, {} phases, {})",
            p.threads,
            p.phases,
            topo.name()
        ),
        runs,
    )
}

/// Run the bursty workload under each policy (seeded like [`run_phase`]).
pub fn run_bursty(topo: &Topology, p: &BurstParams, kinds: &[SchedKind], seed: u64) -> AdaptCmp {
    let mut runs = Vec::with_capacity(kinds.len());
    for &kind in kinds {
        let cfg = SimConfig { seed, ..SimConfig::default() };
        let mut e = engine_with(topo, make_default(kind), cfg);
        build_bursts(&mut e, p);
        let rep = e.run().expect("adaptcmp bursty run");
        runs.push((kind, e, rep.total_time));
    }
    collect(
        format!("bursty load ({}×{} arrivals, {})", p.waves, p.per_wave, topo.name()),
        runs,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    const SEED: u64 = 0x5eed;

    #[test]
    fn adaptive_beats_afs_on_phase_change() {
        // ISSUE-3 acceptance: on the phase-changing workload on the
        // numa(4,4) preset, the adaptive scope must beat fixed
        // machine-wide stealing on makespan *and* locality.
        let topo = Topology::numa(4, 4);
        let p = PhaseParams::for_machine(&topo);
        let c = run_phase(&topo, &p, &[SchedKind::Adaptive, SchedKind::Afs], SEED, None);
        let ad = c.get("adaptive");
        let afs = c.get("afs");
        assert!(ad.makespan > 0 && afs.makespan > 0);
        assert!(
            ad.local_ratio > afs.local_ratio,
            "adaptive {:.3} must beat afs {:.3} on locality",
            ad.local_ratio,
            afs.local_ratio
        );
        assert!(
            ad.makespan < afs.makespan,
            "adaptive {} must beat afs {} on makespan",
            ad.makespan,
            afs.makespan
        );
    }

    #[test]
    fn adaptive_keeps_cross_node_traffic_below_afs_on_bursts() {
        let topo = Topology::numa(4, 4);
        let p = BurstParams::smoke(&topo);
        let c = run_bursty(&topo, &p, &[SchedKind::Adaptive, SchedKind::Afs], SEED);
        let ad = c.get("adaptive");
        let afs = c.get("afs");
        assert!(ad.makespan > 0 && afs.makespan > 0);
        assert!(
            ad.cross_node <= afs.cross_node,
            "adaptive cross-node {} must not exceed afs {}",
            ad.cross_node,
            afs.cross_node
        );
    }

    #[test]
    fn render_lists_every_policy_and_scope_switches() {
        let topo = Topology::numa(2, 2);
        let p = PhaseParams {
            threads: 6,
            phases: 3,
            work: 150_000,
            hot_factor: 2,
            mem_fraction: 0.4,
        };
        let c = run_phase(&topo, &p, &default_kinds(), SEED, None);
        let out = c.render();
        for k in default_kinds() {
            assert!(out.contains(k.label()), "{} missing:\n{out}", k.label());
        }
        assert!(out.contains("widens"));
        assert_eq!(c.harness_rows("phase").len(), default_kinds().len());
    }
}
