//! Chrome trace-event JSON export (`chrome://tracing`, Perfetto).
//!
//! Hand-rolled writer — the crate is dependency-free. Schema: one
//! object `{"traceEvents": [...]}` where everything shares `pid` 0,
//! each CPU is a thread row (`tid` = CPU index, named `cpu<N>` by
//! metadata events) plus an `external` row for records with no CPU
//! context. Each Dispatch→Stop pair becomes one complete `"X"` event
//! (name `t<task>`, `ts`/`dur` in microseconds, args carrying the task
//! id and stop reason); spans still open at the end of the stream are
//! closed at the last seen timestamp so the file always validates.
//! Bursts, steals, bubble moves, regenerations, barrier releases,
//! scope/gang changes, region migrations and worker park/unpark become
//! `"i"` instant events. Enqueue, RegionTouch and PickLatency records
//! are high-frequency raw-stream data; the viewer adds nothing over
//! the analysis tables, so they are not exported.

use super::{Event, Record, StopWhy};

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Engine-ns timestamp → trace-event µs with ns precision kept.
fn us(at: u64) -> String {
    format!("{:.3}", at as f64 / 1000.0)
}

fn meta(name: &str, tid: usize, value: &str) -> String {
    format!(
        "{{\"name\":\"{name}\",\"ph\":\"M\",\"pid\":0,\"tid\":{tid},\
         \"args\":{{\"name\":\"{}\"}}}}",
        esc(value)
    )
}

fn span(task: usize, tid: usize, start: u64, end: u64, why: &str) -> String {
    format!(
        "{{\"name\":\"t{task}\",\"ph\":\"X\",\"pid\":0,\"tid\":{tid},\
         \"ts\":{},\"dur\":{},\"args\":{{\"task\":{task},\"why\":\"{why}\"}}}}",
        us(start),
        us(end.saturating_sub(start))
    )
}

fn instant(name: &str, tid: usize, at: u64, args: &str) -> String {
    format!(
        "{{\"name\":\"{name}\",\"ph\":\"i\",\"pid\":0,\"tid\":{tid},\
         \"ts\":{},\"s\":\"t\",\"args\":{{{args}}}}}",
        us(at)
    )
}

fn why_str(w: StopWhy) -> &'static str {
    match w {
        StopWhy::Yield => "yield",
        StopWhy::Preempt => "preempt",
        StopWhy::Block => "block",
        StopWhy::Terminate => "terminate",
        StopWhy::BackInBubble => "back-in-bubble",
    }
}

/// Render a merged, time-ordered record stream (see
/// [`super::Trace::drain`]) as Chrome trace-event JSON. `n_cpus` sizes
/// the thread rows (records from CPUs ≥ `n_cpus` and contextless
/// records land on the `external` row); `label` names the process.
pub fn chrome_json(records: &[Record], n_cpus: usize, label: &str) -> String {
    let ext = n_cpus;
    let mut ev: Vec<String> = Vec::with_capacity(records.len() + n_cpus + 2);
    ev.push(meta("process_name", 0, label));
    for c in 0..n_cpus {
        ev.push(meta("thread_name", c, &format!("cpu{c}")));
    }
    ev.push(meta("thread_name", ext, "external"));

    // Open Dispatch span per CPU row: (task, start time).
    let mut open: Vec<Option<(usize, u64)>> = vec![None; n_cpus + 1];
    let mut t_max = 0u64;
    let row = |c: usize| if c < n_cpus { c } else { ext };

    for r in records {
        t_max = t_max.max(r.at);
        let ctx = r.cpu.map_or(ext, |c| row(c.0));
        match &r.event {
            Event::Dispatch { task, cpu } => {
                let tid = row(cpu.0);
                // A dispatch over a still-open span (lost Stop record)
                // closes the old one here rather than leaking it.
                if let Some((t, start)) = open[tid].take() {
                    ev.push(span(t, tid, start, r.at, "lost"));
                }
                open[tid] = Some((task.0, r.at));
            }
            Event::Stop { task, cpu, why } => {
                let tid = row(cpu.0);
                match open[tid].take() {
                    Some((t, start)) if t == task.0 => {
                        ev.push(span(t, tid, start, r.at, why_str(*why)));
                    }
                    other => {
                        // Stop without a matching Dispatch (dropped
                        // record): restore and render a zero-width span
                        // so the segment stays visible.
                        open[tid] = other;
                        ev.push(span(task.0, tid, r.at, r.at, why_str(*why)));
                    }
                }
            }
            Event::Burst { bubble, list, released } => {
                ev.push(instant(
                    "burst",
                    ctx,
                    r.at,
                    &format!("\"bubble\":{},\"list\":{},\"released\":{released}", bubble.0, list.0),
                ));
            }
            Event::Steal { task, from, by } => {
                ev.push(instant(
                    "steal",
                    row(by.0),
                    r.at,
                    &format!("\"task\":{},\"from\":{}", task.0, from.0),
                ));
            }
            Event::StealAttempt { by, scope, ok, ns } => {
                if !ok {
                    ev.push(instant(
                        "steal-miss",
                        row(by.0),
                        r.at,
                        &format!("\"scope\":{},\"ns\":{ns}", scope.0),
                    ));
                }
            }
            Event::BubbleDown { bubble, from, to } => {
                ev.push(instant(
                    "bubble-down",
                    ctx,
                    r.at,
                    &format!("\"bubble\":{},\"from\":{},\"to\":{}", bubble.0, from.0, to.0),
                ));
            }
            Event::Regen { bubble, .. } => {
                ev.push(instant("regen", ctx, r.at, &format!("\"bubble\":{}", bubble.0)));
            }
            Event::RegenDone { bubble, list } => {
                ev.push(instant(
                    "regen-done",
                    ctx,
                    r.at,
                    &format!("\"bubble\":{},\"list\":{}", bubble.0, list.0),
                ));
            }
            Event::BarrierRelease { id, waiters } => {
                ev.push(instant(
                    "barrier",
                    ctx,
                    r.at,
                    &format!("\"id\":{id},\"waiters\":{waiters}"),
                ));
            }
            Event::ScopeChange { cpu, from, to, widened } => {
                ev.push(instant(
                    if *widened { "scope-widen" } else { "scope-narrow" },
                    row(cpu.0),
                    r.at,
                    &format!("\"from\":{},\"to\":{}", from.0, to.0),
                ));
            }
            Event::GangResize { gang, from, to, grew } => {
                ev.push(instant(
                    if *grew { "gang-grow" } else { "gang-shrink" },
                    ctx,
                    r.at,
                    &format!("\"gang\":{},\"from\":{},\"to\":{}", gang.0, from.0, to.0),
                ));
            }
            Event::RegionMigrate { region, from, to, bytes } => {
                ev.push(instant(
                    "region-migrate",
                    ctx,
                    r.at,
                    &format!("\"region\":{region},\"from\":{from},\"to\":{to},\"bytes\":{bytes}"),
                ));
            }
            Event::WorkerPark { cpu } => {
                ev.push(instant("park", row(cpu.0), r.at, ""));
            }
            Event::WorkerUnpark { cpu } => {
                ev.push(instant("unpark", row(cpu.0), r.at, ""));
            }
            Event::JobAdmit { job, root } => {
                ev.push(instant(
                    "job-admit",
                    ctx,
                    r.at,
                    &format!("\"job\":{job},\"root\":{}", root.0),
                ));
            }
            Event::JobDone { job, root } => {
                ev.push(instant(
                    "job-done",
                    ctx,
                    r.at,
                    &format!("\"job\":{job},\"root\":{}", root.0),
                ));
            }
            Event::Enqueue { .. } | Event::RegionTouch { .. } | Event::PickLatency { .. } => {}
        }
    }
    // Close dangling spans (run ended mid-segment) at the last seen
    // timestamp so every "X" event is complete.
    for (tid, slot) in open.iter().enumerate() {
        if let Some((t, start)) = slot {
            ev.push(span(*t, tid, *start, t_max.max(*start), "run-end"));
        }
    }

    let mut out = String::from("{\"traceEvents\":[\n");
    out.push_str(&ev.join(",\n"));
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::TaskId;
    use crate::topology::{CpuId, LevelId};
    use crate::util::json;

    fn rec(at: u64, seq: u64, cpu: Option<usize>, event: Event) -> Record {
        Record { at, seq, cpu: cpu.map(CpuId), event }
    }

    fn count(hay: &str, needle: &str) -> usize {
        hay.matches(needle).count()
    }

    #[test]
    fn spans_pair_dispatch_with_stop() {
        let recs = vec![
            rec(1000, 0, Some(0), Event::Dispatch { task: TaskId(7), cpu: CpuId(0) }),
            rec(5000, 1, Some(0), Event::Stop {
                task: TaskId(7),
                cpu: CpuId(0),
                why: StopWhy::Yield,
            }),
        ];
        let j = chrome_json(&recs, 2, "test");
        json::validate(&j).expect("valid JSON");
        assert_eq!(count(&j, "\"ph\":\"X\""), 1);
        assert!(j.contains("\"name\":\"t7\""));
        assert!(j.contains("\"ts\":1.000"));
        assert!(j.contains("\"dur\":4.000"));
        assert!(j.contains("\"why\":\"yield\""));
        assert!(j.contains("\"name\":\"cpu1\""));
        assert!(j.contains("\"name\":\"external\""));
    }

    #[test]
    fn dangling_span_is_closed_at_stream_end() {
        let recs = vec![
            rec(100, 0, Some(1), Event::Dispatch { task: TaskId(3), cpu: CpuId(1) }),
            rec(900, 1, Some(0), Event::WorkerPark { cpu: CpuId(0) }),
        ];
        let j = chrome_json(&recs, 2, "test");
        json::validate(&j).expect("valid JSON");
        assert_eq!(count(&j, "\"ph\":\"X\""), 1);
        assert!(j.contains("\"why\":\"run-end\""));
        assert!(j.contains("\"dur\":0.800"));
    }

    #[test]
    fn instants_and_skips() {
        let recs = vec![
            rec(1, 0, None, Event::Enqueue { task: TaskId(1), list: LevelId(0) }),
            rec(2, 1, Some(0), Event::PickLatency { cpu: CpuId(0), ns: 50, hit: true }),
            rec(3, 2, Some(0), Event::Steal { task: TaskId(1), from: LevelId(0), by: CpuId(0) }),
            rec(4, 3, Some(1), Event::StealAttempt {
                by: CpuId(1),
                scope: LevelId(0),
                ok: false,
                ns: 90,
            }),
            rec(5, 4, None, Event::Burst { bubble: TaskId(9), list: LevelId(0), released: 2 }),
        ];
        let j = chrome_json(&recs, 2, "test");
        json::validate(&j).expect("valid JSON");
        assert_eq!(count(&j, "\"ph\":\"i\""), 3, "steal + steal-miss + burst");
        assert_eq!(count(&j, "\"ph\":\"X\""), 0);
        assert!(!j.contains("Enqueue") && !j.contains("PickLatency"));
        assert!(j.contains("\"name\":\"steal-miss\""));
    }

    #[test]
    fn label_is_escaped() {
        let j = chrome_json(&[], 1, "a\"b\\c");
        json::validate(&j).expect("valid JSON");
        assert!(j.contains("a\\\"b\\\\c"));
    }
}
