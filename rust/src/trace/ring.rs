//! Lock-free bounded event ring: one per trace shard.
//!
//! **Write side** (any thread mapped to this shard): reserve a slot by
//! `head.fetch_add(1)`, then publish the record under a per-slot
//! seqlock — store the *odd* sequence `2·gen+1`, fence, store the
//! payload words, store the *even* sequence `2·gen+2` (Release), where
//! `gen = index >> log2(capacity)` is the lap number. Multiple
//! producers never write the same slot concurrently for the same
//! index, and a producer that laps a slot simply opens a new odd/even
//! pair with a higher generation — a reader can always tell "not yet
//! written", "being written" and "overwritten" apart from the sequence
//! value alone.
//!
//! **Read side** (one drainer at a time — the [`super::Trace`] holds a
//! reader mutex): walk indices from the reader cursor (`tail`) to a
//! `head` snapshot. For index `i` the slot is valid iff its sequence is
//! exactly `2·(i >> shift)+2`; a *smaller* value means the writer has
//! not finished (stop the walk — later records would otherwise be
//! returned twice on the next drain), a *larger* value means the slot
//! was lapped (count it dropped and move on). Payload loads are
//! sandwiched by an acquire fence + sequence re-check, so a torn read
//! from a concurrent lap is detected and discarded, never returned.
//!
//! Capacity is rounded up to a power of two so the index→slot map is a
//! mask and the generation a shift — no division on the hot path.

use std::sync::atomic::{fence, AtomicU64, Ordering};

/// Payload words of one encoded record: `[at, kind|ctx, p0..p3, stamp]`
/// (see `super::Trace` for the packing).
pub(super) const REC_WORDS: usize = 7;

/// One record slot: sequence word + payload, padded to a cache line so
/// neighbouring slots never false-share.
#[repr(align(64))]
struct Slot {
    /// `words[0]` is the seqlock sequence; `words[1..]` the payload.
    words: [AtomicU64; REC_WORDS + 1],
}

impl Slot {
    fn new() -> Slot {
        Slot { words: Default::default() }
    }
}

/// Outcome of reading one slot at a specific reservation index.
enum SlotRead {
    /// The record for this index, read consistently.
    Published([u64; REC_WORDS]),
    /// The writer holding this index has not finished publishing.
    InFlight,
    /// A later lap overwrote (or is overwriting) this index.
    Overwritten,
}

/// Fixed-capacity multi-producer / single-drainer event ring.
pub(super) struct EventRing {
    /// Next reservation index (monotonic; never wraps in practice).
    head: AtomicU64,
    /// Reader cursor: first index not yet drained. Only the drainer
    /// (under the trace's reader mutex) writes it.
    tail: AtomicU64,
    /// Records lost to lapping (writer outran the drainer) — reader
    /// accounting, bumped under the reader mutex.
    dropped: AtomicU64,
    mask: u64,
    shift: u32,
    slots: Box<[Slot]>,
}

impl EventRing {
    /// Ring with capacity `cap` rounded up to a power of two (min 2).
    pub(super) fn new(cap: usize) -> EventRing {
        let cap = cap.max(2).next_power_of_two();
        EventRing {
            head: AtomicU64::new(0),
            tail: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            mask: (cap - 1) as u64,
            shift: cap.trailing_zeros(),
            slots: (0..cap).map(|_| Slot::new()).collect(),
        }
    }

    pub(super) fn capacity(&self) -> usize {
        self.mask as usize + 1
    }

    /// Reserve the next index and publish `rec` under the seqlock
    /// protocol. O(1), lock-free, safe from any number of producers.
    pub(super) fn push(&self, rec: &[u64; REC_WORDS]) {
        let i = self.head.fetch_add(1, Ordering::Relaxed);
        let gen = i >> self.shift;
        let slot = &self.slots[(i & self.mask) as usize];
        slot.words[0].store(2 * gen + 1, Ordering::Relaxed);
        // The odd mark must become visible before any payload word: a
        // reader of the *previous* lap must never pair fresh payload
        // with the stale even sequence it already validated against.
        fence(Ordering::Release);
        for (w, &v) in slot.words[1..].iter().zip(rec) {
            w.store(v, Ordering::Relaxed);
        }
        slot.words[0].store(2 * gen + 2, Ordering::Release);
    }

    fn read_at(&self, i: u64) -> SlotRead {
        let expected = 2 * (i >> self.shift) + 2;
        let slot = &self.slots[(i & self.mask) as usize];
        let s1 = slot.words[0].load(Ordering::Acquire);
        if s1 < expected {
            return SlotRead::InFlight;
        }
        if s1 > expected {
            return SlotRead::Overwritten;
        }
        let mut rec = [0u64; REC_WORDS];
        for (o, w) in rec.iter_mut().zip(&slot.words[1..]) {
            *o = w.load(Ordering::Relaxed);
        }
        // Validate: if a lap started mid-copy the re-read sees an odd
        // or higher sequence and the torn payload is discarded.
        fence(Ordering::Acquire);
        if slot.words[0].load(Ordering::Relaxed) != expected {
            return SlotRead::Overwritten;
        }
        SlotRead::Published(rec)
    }

    /// Consume published records into `out`, advancing the reader
    /// cursor and counting lapped records as dropped. Stops at the
    /// first in-flight slot so every record is drained exactly once.
    /// Caller must hold the trace's reader mutex.
    pub(super) fn drain_into(&self, out: &mut Vec<[u64; REC_WORDS]>) {
        let h = self.head.load(Ordering::Acquire);
        let t = self.tail.load(Ordering::Relaxed);
        let cap = self.mask + 1;
        let start = t.max(h.saturating_sub(cap));
        if start > t {
            // Everything in [t, start) was lapped before we got here.
            self.dropped.fetch_add(start - t, Ordering::Relaxed);
        }
        let mut i = start;
        while i < h {
            match self.read_at(i) {
                SlotRead::Published(r) => {
                    out.push(r);
                    i += 1;
                }
                SlotRead::InFlight => break,
                SlotRead::Overwritten => {
                    self.dropped.fetch_add(1, Ordering::Relaxed);
                    i += 1;
                }
            }
        }
        self.tail.store(i, Ordering::Relaxed);
    }

    /// Copy published records without consuming them (the reader cursor
    /// and drop accounting stay untouched); lapped and in-flight slots
    /// are skipped silently. Caller must hold the trace's reader mutex.
    pub(super) fn snapshot_into(&self, out: &mut Vec<[u64; REC_WORDS]>) {
        let h = self.head.load(Ordering::Acquire);
        let t = self.tail.load(Ordering::Relaxed);
        let mut i = t.max(h.saturating_sub(self.mask + 1));
        while i < h {
            match self.read_at(i) {
                SlotRead::Published(r) => {
                    out.push(r);
                    i += 1;
                }
                SlotRead::InFlight => break,
                SlotRead::Overwritten => i += 1,
            }
        }
    }

    /// Advisory count of records a drain would currently see.
    pub(super) fn len(&self) -> usize {
        let h = self.head.load(Ordering::Relaxed);
        let t = self.tail.load(Ordering::Relaxed);
        h.saturating_sub(t).min(self.mask + 1) as usize
    }

    pub(super) fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Forget everything recorded so far (caller holds the reader
    /// mutex): the cursor jumps to the current head.
    pub(super) fn clear(&self) {
        let h = self.head.load(Ordering::Acquire);
        self.tail.store(h, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(v: u64) -> [u64; REC_WORDS] {
        [v; REC_WORDS]
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        assert_eq!(EventRing::new(3).capacity(), 4);
        assert_eq!(EventRing::new(4).capacity(), 4);
        assert_eq!(EventRing::new(5).capacity(), 8);
        assert_eq!(EventRing::new(0).capacity(), 2);
    }

    #[test]
    fn push_drain_roundtrip() {
        let r = EventRing::new(8);
        for i in 0..5 {
            r.push(&rec(i));
        }
        assert_eq!(r.len(), 5);
        let mut out = Vec::new();
        r.drain_into(&mut out);
        assert_eq!(out, (0..5).map(rec).collect::<Vec<_>>());
        assert_eq!(r.len(), 0);
        assert_eq!(r.dropped(), 0);
        // A second drain returns nothing: exactly-once.
        let mut again = Vec::new();
        r.drain_into(&mut again);
        assert!(again.is_empty());
    }

    #[test]
    fn lapping_drops_oldest_and_counts() {
        let r = EventRing::new(4);
        for i in 0..10 {
            r.push(&rec(i));
        }
        let mut out = Vec::new();
        r.drain_into(&mut out);
        // Only the newest `cap` records survive; the rest are counted.
        assert_eq!(out, (6..10).map(rec).collect::<Vec<_>>());
        assert_eq!(r.dropped(), 6);
    }

    #[test]
    fn snapshot_does_not_consume() {
        let r = EventRing::new(8);
        r.push(&rec(1));
        r.push(&rec(2));
        let mut a = Vec::new();
        r.snapshot_into(&mut a);
        let mut b = Vec::new();
        r.snapshot_into(&mut b);
        assert_eq!(a, b);
        assert_eq!(a.len(), 2);
        let mut d = Vec::new();
        r.drain_into(&mut d);
        assert_eq!(d, a);
    }

    #[test]
    fn clear_skips_to_head() {
        let r = EventRing::new(8);
        for i in 0..3 {
            r.push(&rec(i));
        }
        r.clear();
        assert_eq!(r.len(), 0);
        r.push(&rec(9));
        let mut out = Vec::new();
        r.drain_into(&mut out);
        assert_eq!(out, vec![rec(9)]);
    }
}
