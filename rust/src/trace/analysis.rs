//! Trace analysis (the paper's §6 future work made concrete: "develop
//! analysis tools based on tracing the scheduler at runtime, so as to
//! check and refine scheduling strategies").
//!
//! Consumes a [`super::Trace`] and produces:
//! * per-CPU dispatch/steal counts and a migration matrix,
//! * per-bubble lifecycle summaries (descents, bursts, regenerations),
//! * a list-occupancy profile (which levels actually hold work).

use std::collections::HashMap;

use super::{Event, Record, RegenWhy};
use crate::task::TaskId;
use crate::topology::{CpuId, LevelId, Topology};
use crate::util::fmt::Table;

/// Per-bubble lifecycle counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BubbleStats {
    pub descents: usize,
    pub bursts: usize,
    pub regen_idle: usize,
    pub regen_timeslice: usize,
    pub released_total: usize,
}

/// Aggregated view of one trace.
#[derive(Debug, Clone, Default)]
pub struct Analysis {
    /// Dispatches per CPU.
    pub dispatches: HashMap<usize, usize>,
    /// task -> last cpu seen, used to derive migrations.
    pub migrations: usize,
    /// (from_cpu, to_cpu) -> count.
    pub migration_matrix: HashMap<(usize, usize), usize>,
    /// Steals per thief CPU.
    pub steals: HashMap<usize, usize>,
    /// Enqueues per list.
    pub list_occupancy: HashMap<usize, usize>,
    /// Lifecycle per bubble.
    pub bubbles: HashMap<usize, BubbleStats>,
    /// Barrier releases observed.
    pub barrier_releases: usize,
}

/// Analyse a recorded trace.
pub fn analyse(records: &[Record]) -> Analysis {
    let mut a = Analysis::default();
    let mut last_cpu: HashMap<TaskId, CpuId> = HashMap::new();
    for r in records {
        match &r.event {
            Event::Dispatch { task, cpu } => {
                *a.dispatches.entry(cpu.0).or_default() += 1;
                if let Some(prev) = last_cpu.insert(*task, *cpu) {
                    if prev != *cpu {
                        a.migrations += 1;
                        *a.migration_matrix.entry((prev.0, cpu.0)).or_default() += 1;
                    }
                }
            }
            Event::Steal { by, .. } => {
                *a.steals.entry(by.0).or_default() += 1;
            }
            Event::Enqueue { list, .. } => {
                *a.list_occupancy.entry(list.0).or_default() += 1;
            }
            Event::BubbleDown { bubble, .. } => {
                a.bubbles.entry(bubble.0).or_default().descents += 1;
            }
            Event::Burst { bubble, released, .. } => {
                let b = a.bubbles.entry(bubble.0).or_default();
                b.bursts += 1;
                b.released_total += released;
            }
            Event::Regen { bubble, why } => {
                let b = a.bubbles.entry(bubble.0).or_default();
                match why {
                    RegenWhy::Idle => b.regen_idle += 1,
                    RegenWhy::Timeslice => b.regen_timeslice += 1,
                }
            }
            Event::BarrierRelease { .. } => a.barrier_releases += 1,
            Event::Stop { .. } | Event::RegenDone { .. } => {}
        }
    }
    a
}

impl Analysis {
    /// Load-balance coefficient: stddev/mean of per-CPU dispatch counts
    /// (0 = perfectly even).
    pub fn dispatch_imbalance(&self) -> f64 {
        if self.dispatches.is_empty() {
            return 0.0;
        }
        let xs: Vec<f64> = self.dispatches.values().map(|&v| v as f64).collect();
        crate::util::Summary::of(&xs).cv()
    }

    /// Migration-locality histogram keyed by hierarchical separation:
    /// how far did threads move when they moved?
    pub fn migration_separations(&self, topo: &Topology) -> HashMap<usize, usize> {
        let mut out = HashMap::new();
        for (&(from, to), &n) in &self.migration_matrix {
            let sep = topo.separation(CpuId(from), CpuId(to));
            *out.entry(sep).or_default() += n;
        }
        out
    }

    /// Fraction of enqueues that landed on lists of the given depth.
    pub fn occupancy_by_depth(&self, topo: &Topology) -> HashMap<usize, usize> {
        let mut out = HashMap::new();
        for (&list, &n) in &self.list_occupancy {
            let d = topo.node(LevelId(list)).depth;
            *out.entry(d).or_default() += n;
        }
        out
    }

    /// Human-readable report.
    pub fn render(&self, topo: &Topology) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "dispatches: {} total, imbalance cv {:.3}\n",
            self.dispatches.values().sum::<usize>(),
            self.dispatch_imbalance()
        ));
        out.push_str(&format!(
            "migrations: {}, steals: {}, barrier releases: {}\n",
            self.migrations,
            self.steals.values().sum::<usize>(),
            self.barrier_releases
        ));
        let mut seps: Vec<_> = self.migration_separations(topo).into_iter().collect();
        seps.sort();
        if !seps.is_empty() {
            out.push_str("migration distance histogram (levels crossed -> count):\n");
            for (d, n) in seps {
                out.push_str(&format!("  {d}: {n}\n"));
            }
        }
        let mut depths: Vec<_> = self.occupancy_by_depth(topo).into_iter().collect();
        depths.sort();
        if !depths.is_empty() {
            out.push_str("enqueues by list depth:\n");
            for (d, n) in depths {
                out.push_str(&format!("  depth {d}: {n}\n"));
            }
        }
        if !self.bubbles.is_empty() {
            let mut t = Table::new(&["bubble", "descents", "bursts", "regen(idle)", "regen(slice)", "released"]);
            let mut ids: Vec<_> = self.bubbles.keys().copied().collect();
            ids.sort();
            for id in ids {
                let b = &self.bubbles[&id];
                t.row(&[
                    format!("t{id}"),
                    b.descents.to_string(),
                    b.bursts.to_string(),
                    b.regen_idle.to_string(),
                    b.regen_timeslice.to_string(),
                    b.released_total.to_string(),
                ]);
            }
            out.push_str(&t.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::conduction::{self, HeatParams};
    use crate::apps::StructureMode;
    use crate::topology::Topology;

    fn traced_run(mode: StructureMode) -> (Analysis, Topology) {
        let topo = Topology::numa(2, 2);
        let mut e = crate::apps::engine_for(&topo, mode);
        e.sys.trace.set_enabled(true);
        conduction::build(
            &mut e,
            mode,
            &HeatParams { threads: 4, cycles: 4, work: 200_000, mem_fraction: 0.3 },
        );
        e.run().unwrap();
        (analyse(&e.sys.trace.records()), topo)
    }

    #[test]
    fn bubbles_run_shows_lifecycle() {
        let (a, topo) = traced_run(StructureMode::Bubbles);
        assert!(a.bubbles.values().any(|b| b.bursts >= 1), "{a:?}");
        assert!(a.dispatches.values().sum::<usize>() >= 16);
        assert!(a.barrier_releases >= 3);
        let rendered = a.render(&topo);
        assert!(rendered.contains("bursts"));
        assert!(rendered.contains("dispatches"));
    }

    #[test]
    fn bound_run_has_no_migrations() {
        let (a, _) = traced_run(StructureMode::Bound);
        assert_eq!(a.migrations, 0, "{:?}", a.migration_matrix);
        assert_eq!(a.dispatch_imbalance(), 0.0);
    }

    #[test]
    fn simple_run_migrates_more_than_bubbles() {
        let (simple, _) = traced_run(StructureMode::Simple);
        let (bound, _) = traced_run(StructureMode::Bound);
        assert!(simple.migrations > bound.migrations);
    }

    #[test]
    fn occupancy_depths_match_structure() {
        // Bubbles enqueue on the NUMA level (depth 1); SS only on the
        // machine root (depth 0).
        let (bub, topo) = traced_run(StructureMode::Bubbles);
        let occ = bub.occupancy_by_depth(&topo);
        assert!(occ.get(&1).copied().unwrap_or(0) > 0, "{occ:?}");
        let (ss, topo2) = traced_run(StructureMode::Simple);
        let occ_ss = ss.occupancy_by_depth(&topo2);
        assert_eq!(occ_ss.keys().copied().max(), Some(0), "{occ_ss:?}");
    }

    #[test]
    fn empty_trace_analyses_cleanly() {
        let a = analyse(&[]);
        assert_eq!(a.migrations, 0);
        assert_eq!(a.dispatch_imbalance(), 0.0);
    }
}
