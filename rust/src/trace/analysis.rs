//! Trace analysis (the paper's §6 future work made concrete: "develop
//! analysis tools based on tracing the scheduler at runtime, so as to
//! check and refine scheduling strategies").
//!
//! Consumes a [`super::Trace`] and produces:
//! * per-CPU dispatch/steal counts and a migration matrix,
//! * per-bubble lifecycle summaries (descents, bursts, regenerations),
//! * a list-occupancy profile (which levels actually hold work),
//! * pick/steal latency histograms and per-interval utilization and
//!   local-ratio time series (from the Dispatch→Stop spans and
//!   RegionTouch records).

use std::collections::HashMap;

use super::{Event, Record, RegenWhy};
use crate::metrics::Histogram;
use crate::task::TaskId;
use crate::topology::{CpuId, LevelId, Topology};
use crate::util::fmt::Table;

/// Per-bubble lifecycle counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BubbleStats {
    pub descents: usize,
    pub bursts: usize,
    pub regen_idle: usize,
    pub regen_timeslice: usize,
    pub released_total: usize,
}

/// Aggregated view of one trace.
#[derive(Debug, Clone, Default)]
pub struct Analysis {
    /// Dispatches per CPU.
    pub dispatches: HashMap<usize, usize>,
    /// task -> last cpu seen, used to derive migrations.
    pub migrations: usize,
    /// (from_cpu, to_cpu) -> count.
    pub migration_matrix: HashMap<(usize, usize), usize>,
    /// Steals per thief CPU.
    pub steals: HashMap<usize, usize>,
    /// Enqueues per list.
    pub list_occupancy: HashMap<usize, usize>,
    /// Lifecycle per bubble.
    pub bubbles: HashMap<usize, BubbleStats>,
    /// Barrier releases observed.
    pub barrier_releases: usize,
    /// Host-ns latency of pick calls (from PickLatency records).
    pub pick_latency: Histogram,
    /// Host-ns latency of steal searches (from StealAttempt records).
    pub steal_latency: Histogram,
    /// PickLatency records that returned a task / came up empty.
    pub pick_hits: usize,
    pub pick_misses: usize,
    /// StealAttempt records total / successful.
    pub steal_attempts: usize,
    pub steal_hits: usize,
    /// Adaptive scope moves, moldable gang resizes, region re-homings,
    /// native worker parks.
    pub scope_changes: usize,
    pub gang_resizes: usize,
    pub region_migrations: usize,
    pub parks: usize,
    /// Job-server admissions ([`Event::JobAdmit`]) seen in the stream.
    pub job_admits: usize,
    /// Job-server completions ([`Event::JobDone`]).
    pub job_dones: usize,
    /// Executed Dispatch→Stop segments: `(cpu, start, end)`.
    pub spans: Vec<(usize, u64, u64)>,
    /// RegionTouch records: `(at, local)`.
    pub touches: Vec<(u64, bool)>,
    /// Timestamp range seen across all records (0,0 when empty).
    pub t_min: u64,
    pub t_max: u64,
}

/// Analyse a recorded trace (a merged stream sorted by time, as
/// [`super::Trace::records`]/[`super::Trace::drain`] produce).
pub fn analyse(records: &[Record]) -> Analysis {
    let mut a = Analysis::default();
    let mut last_cpu: HashMap<TaskId, CpuId> = HashMap::new();
    let mut open: HashMap<usize, (TaskId, u64)> = HashMap::new();
    let mut t_min = u64::MAX;
    for r in records {
        t_min = t_min.min(r.at);
        a.t_max = a.t_max.max(r.at);
        match &r.event {
            Event::Dispatch { task, cpu } => {
                *a.dispatches.entry(cpu.0).or_default() += 1;
                if let Some(prev) = last_cpu.insert(*task, *cpu) {
                    if prev != *cpu {
                        a.migrations += 1;
                        *a.migration_matrix.entry((prev.0, cpu.0)).or_default() += 1;
                    }
                }
                open.insert(cpu.0, (*task, r.at));
            }
            Event::Stop { task, cpu, .. } => {
                if let Some((t, start)) = open.remove(&cpu.0) {
                    if t == *task {
                        a.spans.push((cpu.0, start, r.at));
                    } else {
                        open.insert(cpu.0, (t, start));
                    }
                }
            }
            Event::Steal { by, .. } => {
                *a.steals.entry(by.0).or_default() += 1;
            }
            Event::Enqueue { list, .. } => {
                *a.list_occupancy.entry(list.0).or_default() += 1;
            }
            Event::BubbleDown { bubble, .. } => {
                a.bubbles.entry(bubble.0).or_default().descents += 1;
            }
            Event::Burst { bubble, released, .. } => {
                let b = a.bubbles.entry(bubble.0).or_default();
                b.bursts += 1;
                b.released_total += released;
            }
            Event::Regen { bubble, why } => {
                let b = a.bubbles.entry(bubble.0).or_default();
                match why {
                    RegenWhy::Idle => b.regen_idle += 1,
                    RegenWhy::Timeslice => b.regen_timeslice += 1,
                }
            }
            Event::BarrierRelease { .. } => a.barrier_releases += 1,
            Event::PickLatency { ns, hit, .. } => {
                a.pick_latency.record(*ns);
                if *hit {
                    a.pick_hits += 1;
                } else {
                    a.pick_misses += 1;
                }
            }
            Event::StealAttempt { ok, ns, .. } => {
                a.steal_latency.record(*ns);
                a.steal_attempts += 1;
                if *ok {
                    a.steal_hits += 1;
                }
            }
            Event::ScopeChange { .. } => a.scope_changes += 1,
            Event::GangResize { .. } => a.gang_resizes += 1,
            Event::RegionMigrate { .. } => a.region_migrations += 1,
            Event::RegionTouch { local, .. } => a.touches.push((r.at, *local)),
            Event::WorkerPark { .. } => a.parks += 1,
            Event::JobAdmit { .. } => a.job_admits += 1,
            Event::JobDone { .. } => a.job_dones += 1,
            Event::RegenDone { .. } | Event::WorkerUnpark { .. } => {}
        }
    }
    // A segment still running at the trace edge counts up to the last
    // seen timestamp (matches the exporter's dangling-span closing).
    for (cpu, (_, start)) in open {
        a.spans.push((cpu, start, a.t_max.max(start)));
    }
    a.spans.sort_unstable();
    if t_min != u64::MAX {
        a.t_min = t_min;
    }
    a
}

impl Analysis {
    /// Load-balance coefficient: stddev/mean of per-CPU dispatch counts
    /// (0 = perfectly even).
    pub fn dispatch_imbalance(&self) -> f64 {
        if self.dispatches.is_empty() {
            return 0.0;
        }
        let xs: Vec<f64> = self.dispatches.values().map(|&v| v as f64).collect();
        crate::util::Summary::of(&xs).cv()
    }

    /// Migration-locality histogram keyed by hierarchical separation:
    /// how far did threads move when they moved?
    pub fn migration_separations(&self, topo: &Topology) -> HashMap<usize, usize> {
        let mut out = HashMap::new();
        for (&(from, to), &n) in &self.migration_matrix {
            let sep = topo.separation(CpuId(from), CpuId(to));
            *out.entry(sep).or_default() += n;
        }
        out
    }

    /// Fraction of enqueues that landed on lists of the given depth.
    pub fn occupancy_by_depth(&self, topo: &Topology) -> HashMap<usize, usize> {
        let mut out = HashMap::new();
        for (&list, &n) in &self.list_occupancy {
            let d = topo.node(LevelId(list)).depth;
            *out.entry(d).or_default() += n;
        }
        out
    }

    /// Per-interval CPU utilization: the `(t_min, t_max)` range split
    /// into `intervals` equal windows, each reporting busy-time (from
    /// the Dispatch→Stop spans, summed over CPUs) divided by
    /// `n_cpus × window`. Empty when the trace has no time extent.
    pub fn utilization_timeline(&self, n_cpus: usize, intervals: usize) -> Vec<f64> {
        let extent = self.t_max.saturating_sub(self.t_min);
        if extent == 0 || intervals == 0 || n_cpus == 0 {
            return Vec::new();
        }
        let mut busy = vec![0.0f64; intervals];
        let w = extent as f64 / intervals as f64;
        for &(_, s, e) in &self.spans {
            let (s, e) = (s.max(self.t_min), e.min(self.t_max));
            if e <= s {
                continue;
            }
            let lo = ((s - self.t_min) as f64 / w) as usize;
            let hi = (((e - self.t_min) as f64 / w).ceil() as usize).min(intervals);
            for (i, b) in busy.iter_mut().enumerate().take(hi).skip(lo) {
                let win_s = self.t_min as f64 + i as f64 * w;
                let overlap = (e as f64).min(win_s + w) - (s as f64).max(win_s);
                if overlap > 0.0 {
                    *b += overlap;
                }
            }
        }
        busy.iter().map(|&b| (b / (w * n_cpus as f64)).min(1.0)).collect()
    }

    /// Per-interval memory locality: `(window start, local ratio,
    /// touches)` per window with at least one RegionTouch record.
    pub fn local_ratio_timeline(&self, intervals: usize) -> Vec<(u64, f64, usize)> {
        let extent = self.t_max.saturating_sub(self.t_min);
        if extent == 0 || intervals == 0 || self.touches.is_empty() {
            return Vec::new();
        }
        let mut local = vec![0usize; intervals];
        let mut total = vec![0usize; intervals];
        let w = extent as f64 / intervals as f64;
        for &(at, is_local) in &self.touches {
            let i = (((at.saturating_sub(self.t_min)) as f64 / w) as usize).min(intervals - 1);
            total[i] += 1;
            if is_local {
                local[i] += 1;
            }
        }
        (0..intervals)
            .filter(|&i| total[i] > 0)
            .map(|i| {
                let start = self.t_min + (i as f64 * w) as u64;
                (start, local[i] as f64 / total[i] as f64, total[i])
            })
            .collect()
    }

    /// Human-readable report.
    pub fn render(&self, topo: &Topology) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "dispatches: {} total, imbalance cv {:.3}\n",
            self.dispatches.values().sum::<usize>(),
            self.dispatch_imbalance()
        ));
        out.push_str(&format!(
            "migrations: {}, steals: {}, barrier releases: {}\n",
            self.migrations,
            self.steals.values().sum::<usize>(),
            self.barrier_releases
        ));
        let mut seps: Vec<_> = self.migration_separations(topo).into_iter().collect();
        seps.sort();
        if !seps.is_empty() {
            out.push_str("migration distance histogram (levels crossed -> count):\n");
            for (d, n) in seps {
                out.push_str(&format!("  {d}: {n}\n"));
            }
        }
        let mut depths: Vec<_> = self.occupancy_by_depth(topo).into_iter().collect();
        depths.sort();
        if !depths.is_empty() {
            out.push_str("enqueues by list depth:\n");
            for (d, n) in depths {
                out.push_str(&format!("  depth {d}: {n}\n"));
            }
        }
        if !self.bubbles.is_empty() {
            let mut t = Table::new(&["bubble", "descents", "bursts", "regen(idle)", "regen(slice)", "released"]);
            let mut ids: Vec<_> = self.bubbles.keys().copied().collect();
            ids.sort();
            for id in ids {
                let b = &self.bubbles[&id];
                t.row(&[
                    format!("t{id}"),
                    b.descents.to_string(),
                    b.bursts.to_string(),
                    b.regen_idle.to_string(),
                    b.regen_timeslice.to_string(),
                    b.released_total.to_string(),
                ]);
            }
            out.push_str(&t.render());
        }
        if self.pick_hits + self.pick_misses > 0 {
            out.push_str(&format!(
                "picks timed: {} hit, {} empty\n",
                self.pick_hits, self.pick_misses
            ));
            out.push_str(&self.pick_latency.render("pick latency ns"));
        }
        if self.steal_attempts > 0 {
            out.push_str(&format!(
                "steal searches: {} ({} hit)\n",
                self.steal_attempts, self.steal_hits
            ));
            out.push_str(&self.steal_latency.render("steal latency ns"));
        }
        if self.scope_changes + self.gang_resizes + self.region_migrations + self.parks > 0 {
            out.push_str(&format!(
                "scope changes: {}, gang resizes: {}, region migrations: {}, parks: {}\n",
                self.scope_changes, self.gang_resizes, self.region_migrations, self.parks
            ));
        }
        let util = self.utilization_timeline(topo.n_cpus(), 10);
        if !util.is_empty() {
            out.push_str("utilization timeline (10 windows):\n ");
            for u in &util {
                out.push_str(&format!(" {u:.2}"));
            }
            out.push('\n');
        }
        let locality = self.local_ratio_timeline(10);
        if !locality.is_empty() {
            out.push_str("local-ratio timeline (window start, ratio, touches):\n");
            for (start, ratio, n) in &locality {
                out.push_str(&format!("  {start:>12}  {ratio:.3}  {n}\n"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::conduction::{self, HeatParams};
    use crate::apps::StructureMode;
    use crate::topology::Topology;

    fn traced_run(mode: StructureMode) -> (Analysis, Topology) {
        let topo = Topology::numa(2, 2);
        let mut e = crate::apps::engine_for(&topo, mode);
        e.sys.trace.set_enabled(true);
        conduction::build(
            &mut e,
            mode,
            &HeatParams { threads: 4, cycles: 4, work: 200_000, mem_fraction: 0.3 },
        );
        e.run().unwrap();
        (analyse(&e.sys.trace.records()), topo)
    }

    #[test]
    fn bubbles_run_shows_lifecycle() {
        let (a, topo) = traced_run(StructureMode::Bubbles);
        assert!(a.bubbles.values().any(|b| b.bursts >= 1), "{a:?}");
        assert!(a.dispatches.values().sum::<usize>() >= 16);
        assert!(a.barrier_releases >= 3);
        let rendered = a.render(&topo);
        assert!(rendered.contains("bursts"));
        assert!(rendered.contains("dispatches"));
    }

    #[test]
    fn bound_run_has_no_migrations() {
        let (a, _) = traced_run(StructureMode::Bound);
        assert_eq!(a.migrations, 0, "{:?}", a.migration_matrix);
        assert_eq!(a.dispatch_imbalance(), 0.0);
    }

    #[test]
    fn simple_run_migrates_more_than_bubbles() {
        let (simple, _) = traced_run(StructureMode::Simple);
        let (bound, _) = traced_run(StructureMode::Bound);
        assert!(simple.migrations > bound.migrations);
    }

    #[test]
    fn occupancy_depths_match_structure() {
        // Bubbles enqueue on the NUMA level (depth 1); SS only on the
        // machine root (depth 0).
        let (bub, topo) = traced_run(StructureMode::Bubbles);
        let occ = bub.occupancy_by_depth(&topo);
        assert!(occ.get(&1).copied().unwrap_or(0) > 0, "{occ:?}");
        let (ss, topo2) = traced_run(StructureMode::Simple);
        let occ_ss = ss.occupancy_by_depth(&topo2);
        assert_eq!(occ_ss.keys().copied().max(), Some(0), "{occ_ss:?}");
    }

    #[test]
    fn empty_trace_analyses_cleanly() {
        let a = analyse(&[]);
        assert_eq!(a.migrations, 0);
        assert_eq!(a.dispatch_imbalance(), 0.0);
        assert!(a.utilization_timeline(4, 10).is_empty());
        assert!(a.local_ratio_timeline(10).is_empty());
    }

    #[test]
    fn spans_and_timelines_from_synthetic_stream() {
        use crate::trace::StopWhy;
        let rec = |at: u64, seq: u64, event: Event| Record { at, seq, cpu: Some(CpuId(0)), event };
        let recs = vec![
            rec(0, 0, Event::Dispatch { task: TaskId(1), cpu: CpuId(0) }),
            rec(200, 1, Event::RegionTouch { region: 0, cpu: CpuId(0), home: 0, local: true }),
            rec(500, 2, Event::Stop { task: TaskId(1), cpu: CpuId(0), why: StopWhy::Yield }),
            rec(700, 3, Event::PickLatency { cpu: CpuId(0), ns: 1000, hit: false }),
            rec(
                800,
                4,
                Event::StealAttempt { by: CpuId(0), scope: LevelId(0), ok: true, ns: 3 },
            ),
            rec(900, 5, Event::RegionTouch { region: 1, cpu: CpuId(0), home: 1, local: false }),
            rec(1000, 6, Event::WorkerPark { cpu: CpuId(0) }),
        ];
        let a = analyse(&recs);
        assert_eq!(a.spans, vec![(0, 0, 500)]);
        assert_eq!((a.t_min, a.t_max), (0, 1000));
        assert_eq!(a.pick_misses, 1);
        assert_eq!((a.steal_attempts, a.steal_hits), (1, 1));
        assert_eq!(a.pick_latency.count(10), 1, "1000ns lands in bucket 10");
        assert_eq!(a.steal_latency.count(2), 1, "3ns lands in bucket 2");
        assert_eq!(a.parks, 1);
        // One CPU busy for [0,500) of [0,1000): halves of the timeline.
        let util = analyse(&recs).utilization_timeline(1, 2);
        assert!((util[0] - 1.0).abs() < 1e-9 && util[1].abs() < 1e-9, "{util:?}");
        let loc = a.local_ratio_timeline(2);
        assert_eq!(loc.len(), 2);
        assert!((loc[0].1 - 1.0).abs() < 1e-9 && loc[1].1.abs() < 1e-9, "{loc:?}");
    }

    #[test]
    fn dangling_span_closes_at_trace_edge() {
        let recs = vec![
            Record {
                at: 100,
                seq: 0,
                cpu: Some(CpuId(1)),
                event: Event::Dispatch { task: TaskId(2), cpu: CpuId(1) },
            },
            Record {
                at: 400,
                seq: 1,
                cpu: Some(CpuId(0)),
                event: Event::WorkerPark { cpu: CpuId(0) },
            },
        ];
        let a = analyse(&recs);
        assert_eq!(a.spans, vec![(1, 100, 400)]);
    }
}
