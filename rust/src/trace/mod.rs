//! Scheduler event tracing (the paper's §6 future work: "analysis tools
//! based on tracing the scheduler at runtime, so as to check and refine
//! scheduling strategies").
//!
//! Always compiled in, near-zero cost while disabled: the hot-path
//! check is one atomic load ([`Trace::enabled`]), and callers that
//! would pay to *construct* an event go through
//! [`crate::sched::System::trace_emit`], which checks first.
//!
//! # Ring / drain protocol
//!
//! Recording is sharded: one fixed-capacity lock-free ring
//! ([`ring::EventRing`]) per virtual CPU plus one *external* shard for
//! threads with no CPU context. A writer picks its shard through the
//! owner-identity thread-local ([`crate::rq::owner::current_cpu`]) —
//! the same identity that routes the runqueue fast lane — so native
//! workers and the simulator's virtual CPUs record without ever
//! contending on a lock. Each record carries the engine timestamp, a
//! globally ordered emission stamp (one shared `fetch_add`), and the
//! recording CPU; the merge step sorts by `(at, stamp)` into one
//! time-ordered stream. Per-slot seqlocks make drain-while-recording
//! well-defined: [`Trace::drain`] returns every published record
//! exactly once, counts lapped records as dropped, and never returns a
//! torn read (see `ring` for the memory-ordering argument).
//!
//! Events are stored word-encoded (7×u64 per record, one cache line
//! with the seqlock word); [`Event::encode`]/[`Event::decode`]
//! round-trip every variant.
//!
//! # Export schema
//!
//! [`export::chrome_json`] renders a merged stream as Chrome
//! trace-event JSON (`chrome://tracing`, Perfetto): one row (`tid`) per
//! CPU plus an `external` row, a complete `"X"` span per
//! Dispatch→Stop segment (name `t<task>`, `ts`/`dur` in µs from the
//! engine-ns timestamps), and `"i"` instant events for bursts, steals,
//! migrations, scope/gang changes and worker park/unpark.
//! [`analysis::analyse`] consumes the same stream for the §6 tables,
//! utilization timelines and latency histograms.

pub mod analysis;
pub mod export;
mod ring;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::task::TaskId;
use crate::topology::{CpuId, LevelId};

use ring::{EventRing, REC_WORDS};

/// What happened.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// Task enqueued on a list.
    Enqueue { task: TaskId, list: LevelId },
    /// Thread dispatched on a CPU.
    Dispatch { task: TaskId, cpu: CpuId },
    /// Thread stopped running (yield/block/terminate).
    Stop { task: TaskId, cpu: CpuId, why: StopWhy },
    /// Bubble moved one level down towards a CPU (Figure 3 (b)-(c)).
    BubbleDown { bubble: TaskId, from: LevelId, to: LevelId },
    /// Bubble burst on a list (Figure 3 (d)).
    Burst { bubble: TaskId, list: LevelId, released: usize },
    /// Bubble regeneration began (§3.3.3).
    Regen { bubble: TaskId, why: RegenWhy },
    /// Regenerated bubble re-queued (closed again, moved up).
    RegenDone { bubble: TaskId, list: LevelId },
    /// A task was stolen from a list by a remote CPU's scheduler.
    Steal { task: TaskId, from: LevelId, by: CpuId },
    /// Barrier crossed by all participants.
    BarrierRelease { id: usize, waiters: usize },
    /// One `Scheduler::pick` call on `cpu` took `ns` host nanoseconds
    /// (`hit` = it returned a task). Native workers time every pick;
    /// the simulator reports the host-side cost of its pick calls
    /// while `at` stays in simulated cycles.
    PickLatency { cpu: CpuId, ns: u64, hit: bool },
    /// One steal search by `by` over `scope` took `ns` host
    /// nanoseconds (`ok` = it found a task).
    StealAttempt { by: CpuId, scope: LevelId, ok: bool, ns: u64 },
    /// Adaptive policy: `cpu`'s steal scope moved `from` → `to`.
    ScopeChange { cpu: CpuId, from: LevelId, to: LevelId, widened: bool },
    /// Moldable policy: `gang`'s component moved `from` → `to`.
    GangResize { gang: TaskId, from: LevelId, to: LevelId, grew: bool },
    /// A region's memory was re-homed `from` → `to` NUMA node
    /// (next-touch migration).
    RegionMigrate { region: usize, from: usize, to: usize, bytes: u64 },
    /// A memory touch on `region` by `cpu` resolved to NUMA node
    /// `home` (`local` = same node as the toucher).
    RegionTouch { region: usize, cpu: CpuId, home: usize, local: bool },
    /// A native worker parked (nothing pickable).
    WorkerPark { cpu: CpuId },
    /// A native worker resumed after parking.
    WorkerUnpark { cpu: CpuId },
    /// Job server: job `job` (its root task `root`) was admitted —
    /// the root's first wake reached the scheduler.
    JobAdmit { job: u64, root: TaskId },
    /// Job server: every member of job `job` terminated.
    JobDone { job: u64, root: TaskId },
}

/// Why a thread stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopWhy {
    Yield,
    Preempt,
    Block,
    Terminate,
    /// Re-entered its regenerating bubble (§4).
    BackInBubble,
}

impl StopWhy {
    fn code(self) -> u64 {
        match self {
            StopWhy::Yield => 0,
            StopWhy::Preempt => 1,
            StopWhy::Block => 2,
            StopWhy::Terminate => 3,
            StopWhy::BackInBubble => 4,
        }
    }

    fn from_code(c: u64) -> Option<StopWhy> {
        Some(match c {
            0 => StopWhy::Yield,
            1 => StopWhy::Preempt,
            2 => StopWhy::Block,
            3 => StopWhy::Terminate,
            4 => StopWhy::BackInBubble,
            _ => return None,
        })
    }
}

/// Why a bubble regenerated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RegenWhy {
    /// An idle processor pulled it up to rebalance.
    Idle,
    /// Its time slice expired (gang scheduling).
    Timeslice,
}

impl RegenWhy {
    fn code(self) -> u64 {
        match self {
            RegenWhy::Idle => 0,
            RegenWhy::Timeslice => 1,
        }
    }

    fn from_code(c: u64) -> Option<RegenWhy> {
        Some(match c {
            0 => RegenWhy::Idle,
            1 => RegenWhy::Timeslice,
            _ => return None,
        })
    }
}

fn b2w(b: bool) -> u64 {
    b as u64
}

impl Event {
    /// Word-encode into `(kind, payload)`; [`Event::decode`] inverts.
    pub(crate) fn encode(&self) -> (u8, [u64; 4]) {
        use Event::*;
        match *self {
            Enqueue { task, list } => (0, [task.0 as u64, list.0 as u64, 0, 0]),
            Dispatch { task, cpu } => (1, [task.0 as u64, cpu.0 as u64, 0, 0]),
            Stop { task, cpu, why } => (2, [task.0 as u64, cpu.0 as u64, why.code(), 0]),
            BubbleDown { bubble, from, to } => {
                (3, [bubble.0 as u64, from.0 as u64, to.0 as u64, 0])
            }
            Burst { bubble, list, released } => {
                (4, [bubble.0 as u64, list.0 as u64, released as u64, 0])
            }
            Regen { bubble, why } => (5, [bubble.0 as u64, why.code(), 0, 0]),
            RegenDone { bubble, list } => (6, [bubble.0 as u64, list.0 as u64, 0, 0]),
            Steal { task, from, by } => (7, [task.0 as u64, from.0 as u64, by.0 as u64, 0]),
            BarrierRelease { id, waiters } => (8, [id as u64, waiters as u64, 0, 0]),
            PickLatency { cpu, ns, hit } => (9, [cpu.0 as u64, ns, b2w(hit), 0]),
            StealAttempt { by, scope, ok, ns } => {
                (10, [by.0 as u64, scope.0 as u64, b2w(ok), ns])
            }
            ScopeChange { cpu, from, to, widened } => {
                (11, [cpu.0 as u64, from.0 as u64, to.0 as u64, b2w(widened)])
            }
            GangResize { gang, from, to, grew } => {
                (12, [gang.0 as u64, from.0 as u64, to.0 as u64, b2w(grew)])
            }
            RegionMigrate { region, from, to, bytes } => {
                (13, [region as u64, from as u64, to as u64, bytes])
            }
            RegionTouch { region, cpu, home, local } => {
                (14, [region as u64, cpu.0 as u64, home as u64, b2w(local)])
            }
            WorkerPark { cpu } => (15, [cpu.0 as u64, 0, 0, 0]),
            WorkerUnpark { cpu } => (16, [cpu.0 as u64, 0, 0, 0]),
            JobAdmit { job, root } => (17, [job, root.0 as u64, 0, 0]),
            JobDone { job, root } => (18, [job, root.0 as u64, 0, 0]),
        }
    }

    /// Inverse of [`Event::encode`] (`None` on an unknown kind or
    /// enum code — a corrupt slot is dropped, not propagated).
    pub(crate) fn decode(kind: u8, p: &[u64; 4]) -> Option<Event> {
        use Event::*;
        Some(match kind {
            0 => Enqueue { task: TaskId(p[0] as usize), list: LevelId(p[1] as usize) },
            1 => Dispatch { task: TaskId(p[0] as usize), cpu: CpuId(p[1] as usize) },
            2 => Stop {
                task: TaskId(p[0] as usize),
                cpu: CpuId(p[1] as usize),
                why: StopWhy::from_code(p[2])?,
            },
            3 => BubbleDown {
                bubble: TaskId(p[0] as usize),
                from: LevelId(p[1] as usize),
                to: LevelId(p[2] as usize),
            },
            4 => Burst {
                bubble: TaskId(p[0] as usize),
                list: LevelId(p[1] as usize),
                released: p[2] as usize,
            },
            5 => Regen { bubble: TaskId(p[0] as usize), why: RegenWhy::from_code(p[1])? },
            6 => RegenDone { bubble: TaskId(p[0] as usize), list: LevelId(p[1] as usize) },
            7 => Steal {
                task: TaskId(p[0] as usize),
                from: LevelId(p[1] as usize),
                by: CpuId(p[2] as usize),
            },
            8 => BarrierRelease { id: p[0] as usize, waiters: p[1] as usize },
            9 => PickLatency { cpu: CpuId(p[0] as usize), ns: p[1], hit: p[2] != 0 },
            10 => StealAttempt {
                by: CpuId(p[0] as usize),
                scope: LevelId(p[1] as usize),
                ok: p[2] != 0,
                ns: p[3],
            },
            11 => ScopeChange {
                cpu: CpuId(p[0] as usize),
                from: LevelId(p[1] as usize),
                to: LevelId(p[2] as usize),
                widened: p[3] != 0,
            },
            12 => GangResize {
                gang: TaskId(p[0] as usize),
                from: LevelId(p[1] as usize),
                to: LevelId(p[2] as usize),
                grew: p[3] != 0,
            },
            13 => RegionMigrate {
                region: p[0] as usize,
                from: p[1] as usize,
                to: p[2] as usize,
                bytes: p[3],
            },
            14 => RegionTouch {
                region: p[0] as usize,
                cpu: CpuId(p[1] as usize),
                home: p[2] as usize,
                local: p[3] != 0,
            },
            15 => WorkerPark { cpu: CpuId(p[0] as usize) },
            16 => WorkerUnpark { cpu: CpuId(p[0] as usize) },
            17 => JobAdmit { job: p[0], root: TaskId(p[1] as usize) },
            18 => JobDone { job: p[0], root: TaskId(p[1] as usize) },
            _ => return None,
        })
    }
}

/// A timestamped trace record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Record {
    /// Engine time (simulated cycles, or wall ns for the native
    /// executor — see `System::now`).
    pub at: u64,
    /// Global emission stamp: a total order across all shards,
    /// tie-breaking records with equal `at`.
    pub seq: u64,
    /// CPU context of the recording thread (`None` = recorded outside
    /// any worker, e.g. from the process main thread).
    pub cpu: Option<CpuId>,
    pub event: Event,
}

/// Sharded bounded trace buffer (see the module docs for the
/// ring/drain protocol).
pub struct Trace {
    enabled: AtomicBool,
    /// Per-shard capacity (rounded up to a power of two on init).
    cap: usize,
    /// Shards `0..n_cpus` are per-CPU; shard `n_cpus` is external.
    n_cpus: usize,
    stamp: AtomicU64,
    /// Records whose stored words failed to decode (corruption guard;
    /// counted into [`Trace::dropped`]).
    decode_drops: AtomicU64,
    /// Rings are allocated on first enable, not up front: a disabled
    /// trace costs one pointer per system.
    shards: OnceLock<Box<[EventRing]>>,
    /// Serialises the reader side (drain/records/clear): the tail
    /// cursors and drop counters are reader-owned state.
    reader: Mutex<()>,
}

impl std::fmt::Debug for Trace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Trace")
            .field("enabled", &self.enabled())
            .field("n_cpus", &self.n_cpus)
            .field("len", &self.len())
            .finish()
    }
}

impl Default for Trace {
    fn default() -> Self {
        Trace::new(1 << 16)
    }
}

impl Trace {
    /// Trace with no per-CPU shards (everything lands in the external
    /// shard of capacity `cap`). [`Trace::for_cpus`] is what engines
    /// use.
    pub fn new(cap: usize) -> Trace {
        Trace::for_cpus(0, cap)
    }

    /// Trace with one ring per CPU plus the external shard, each of
    /// capacity `cap` (rounded up to a power of two).
    pub fn for_cpus(n_cpus: usize, cap: usize) -> Trace {
        Trace {
            enabled: AtomicBool::new(false),
            cap,
            n_cpus,
            stamp: AtomicU64::new(0),
            decode_drops: AtomicU64::new(0),
            shards: OnceLock::new(),
            reader: Mutex::new(()),
        }
    }

    fn shards(&self) -> &[EventRing] {
        self.shards.get_or_init(|| (0..=self.n_cpus).map(|_| EventRing::new(self.cap)).collect())
    }

    /// Turn recording on/off. Enabling allocates the shards *before*
    /// publishing the flag, so a concurrent [`Trace::emit`] that sees
    /// `enabled` always finds them.
    pub fn set_enabled(&self, on: bool) {
        if on {
            self.shards();
        }
        self.enabled.store(on, Ordering::Release);
    }

    /// Is recording on?
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Acquire)
    }

    /// Per-shard ring capacity (after power-of-two rounding).
    pub fn shard_capacity(&self) -> usize {
        self.cap.max(2).next_power_of_two()
    }

    /// Record an event (no-op when disabled). Lock-free: one atomic
    /// stamp increment plus a seqlock slot publish in this thread's
    /// shard. Callers that would pay to construct `event` should check
    /// [`Trace::enabled`] first (`System::trace_emit` does).
    pub fn emit(&self, at: u64, event: Event) {
        if !self.enabled() {
            return;
        }
        let shards = self.shards();
        let idx = match crate::rq::owner::current_cpu() {
            Some(c) if c.0 < self.n_cpus => c.0,
            _ => self.n_cpus,
        };
        let (kind, p) = event.encode();
        // kind in bits 0..8; (cpu context + 1) above (0 = external).
        let ctx = if idx < self.n_cpus { idx as u64 + 1 } else { 0 };
        let kindctx = kind as u64 | (ctx << 8);
        let stamp = self.stamp.fetch_add(1, Ordering::Relaxed);
        shards[idx].push(&[at, kindctx, p[0], p[1], p[2], p[3], stamp]);
    }

    fn decode_sorted(&self, raw: Vec<[u64; REC_WORDS]>) -> Vec<Record> {
        let mut out = Vec::with_capacity(raw.len());
        for w in &raw {
            let kind = (w[1] & 0xff) as u8;
            let ctx = w[1] >> 8;
            let cpu = if ctx == 0 { None } else { Some(CpuId(ctx as usize - 1)) };
            match Event::decode(kind, &[w[2], w[3], w[4], w[5]]) {
                Some(event) => out.push(Record { at: w[0], seq: w[6], cpu, event }),
                None => {
                    self.decode_drops.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        out.sort_by_key(|r| (r.at, r.seq));
        out
    }

    /// Non-consuming snapshot of the recorded events, merged across
    /// shards into one time-ordered stream (sorted by `(at, seq)`).
    /// Safe while writers are recording; lapped slots are skipped.
    pub fn records(&self) -> Vec<Record> {
        let _r = self.reader.lock().unwrap();
        let Some(shards) = self.shards.get() else {
            return Vec::new();
        };
        let mut raw = Vec::new();
        for s in shards.iter() {
            s.snapshot_into(&mut raw);
        }
        self.decode_sorted(raw)
    }

    /// Consume the recorded events: every published record is returned
    /// exactly once (across any sequence of drains), merged and
    /// time-ordered. Records lapped before the drain reached them are
    /// counted in [`Trace::dropped`]. Safe while writers are recording.
    pub fn drain(&self) -> Vec<Record> {
        let _r = self.reader.lock().unwrap();
        let Some(shards) = self.shards.get() else {
            return Vec::new();
        };
        let mut raw = Vec::new();
        for s in shards.iter() {
            s.drain_into(&mut raw);
        }
        self.decode_sorted(raw)
    }

    /// Records lost so far: lapped by writers before a drain got to
    /// them, plus any that failed to decode.
    pub fn dropped(&self) -> u64 {
        self.decode_drops.load(Ordering::Relaxed)
            + self.shards.get().map_or(0, |s| s.iter().map(|r| r.dropped()).sum())
    }

    /// Advisory number of currently drainable records (summed over
    /// shards; concurrent writers may move it).
    pub fn len(&self) -> usize {
        self.shards.get().map_or(0, |s| s.iter().map(|r| r.len()).sum())
    }

    /// No events recorded?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all records (the reader cursors jump to the write heads).
    pub fn clear(&self) {
        let _r = self.reader.lock().unwrap();
        if let Some(shards) = self.shards.get() {
            for s in shards.iter() {
                s.clear();
            }
        }
    }

    /// Human-readable dump of the merged stream.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        for r in self.records() {
            out.push_str(&format!("{:>12}  {:?}\n", r.at, r.event));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_by_default() {
        let t = Trace::default();
        t.emit(0, Event::Dispatch { task: TaskId(0), cpu: CpuId(0) });
        assert!(t.is_empty());
        assert!(t.records().is_empty());
    }

    #[test]
    fn records_when_enabled() {
        let t = Trace::default();
        t.set_enabled(true);
        t.emit(5, Event::Burst { bubble: TaskId(1), list: LevelId(0), released: 4 });
        assert_eq!(t.len(), 1);
        assert_eq!(t.records()[0].at, 5);
        assert!(t.dump().contains("Burst"));
    }

    #[test]
    fn ring_drops_oldest_at_capacity() {
        // cap 3 rounds to 4 slots; 6 emits keep the newest 4.
        let t = Trace::new(3);
        t.set_enabled(true);
        assert_eq!(t.shard_capacity(), 4);
        for i in 0..6 {
            t.emit(i, Event::Dispatch { task: TaskId(i as usize), cpu: CpuId(0) });
        }
        let r = t.drain();
        assert_eq!(r.len(), 4);
        assert_eq!(r[0].at, 2);
        assert_eq!(r[3].at, 5);
        assert_eq!(t.dropped(), 2);
    }

    #[test]
    fn drain_consumes_snapshot_does_not() {
        let t = Trace::new(16);
        t.set_enabled(true);
        t.emit(1, Event::WorkerPark { cpu: CpuId(0) });
        t.emit(2, Event::WorkerUnpark { cpu: CpuId(0) });
        assert_eq!(t.records().len(), 2);
        assert_eq!(t.records().len(), 2, "records() must not consume");
        assert_eq!(t.drain().len(), 2);
        assert!(t.drain().is_empty(), "drain() must consume exactly once");
    }

    #[test]
    fn shard_attribution_follows_owner_context() {
        let t = Trace::for_cpus(2, 16);
        t.set_enabled(true);
        crate::rq::owner::set_current_cpu(Some(CpuId(1)));
        t.emit(1, Event::WorkerPark { cpu: CpuId(1) });
        crate::rq::owner::set_current_cpu(None);
        t.emit(2, Event::WorkerUnpark { cpu: CpuId(1) });
        let r = t.records();
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].cpu, Some(CpuId(1)));
        assert_eq!(r[1].cpu, None, "no owner context lands in the external shard");
    }

    #[test]
    fn merged_stream_is_time_ordered_with_stamp_tiebreak() {
        let t = Trace::for_cpus(2, 16);
        t.set_enabled(true);
        // Same `at` from two shards: the emission stamp orders them.
        crate::rq::owner::set_current_cpu(Some(CpuId(0)));
        t.emit(7, Event::WorkerPark { cpu: CpuId(0) });
        crate::rq::owner::set_current_cpu(Some(CpuId(1)));
        t.emit(7, Event::WorkerPark { cpu: CpuId(1) });
        t.emit(3, Event::WorkerUnpark { cpu: CpuId(1) });
        crate::rq::owner::set_current_cpu(None);
        let r = t.records();
        assert_eq!(r[0].at, 3);
        assert_eq!(r[1].cpu, Some(CpuId(0)));
        assert_eq!(r[2].cpu, Some(CpuId(1)));
        assert!(r[1].seq < r[2].seq);
    }

    #[test]
    fn encode_decode_roundtrips_every_variant() {
        let evs = vec![
            Event::Enqueue { task: TaskId(1), list: LevelId(2) },
            Event::Dispatch { task: TaskId(3), cpu: CpuId(4) },
            Event::Stop { task: TaskId(5), cpu: CpuId(6), why: StopWhy::BackInBubble },
            Event::BubbleDown { bubble: TaskId(7), from: LevelId(0), to: LevelId(1) },
            Event::Burst { bubble: TaskId(8), list: LevelId(2), released: 9 },
            Event::Regen { bubble: TaskId(10), why: RegenWhy::Timeslice },
            Event::RegenDone { bubble: TaskId(11), list: LevelId(3) },
            Event::Steal { task: TaskId(12), from: LevelId(4), by: CpuId(5) },
            Event::BarrierRelease { id: 13, waiters: 14 },
            Event::PickLatency { cpu: CpuId(1), ns: 1500, hit: true },
            Event::StealAttempt { by: CpuId(2), scope: LevelId(0), ok: false, ns: 88 },
            Event::ScopeChange { cpu: CpuId(3), from: LevelId(6), to: LevelId(2), widened: true },
            Event::GangResize { gang: TaskId(15), from: LevelId(1), to: LevelId(0), grew: true },
            Event::RegionMigrate { region: 16, from: 0, to: 3, bytes: 1 << 20 },
            Event::RegionTouch { region: 17, cpu: CpuId(7), home: 1, local: false },
            Event::WorkerPark { cpu: CpuId(8) },
            Event::WorkerUnpark { cpu: CpuId(9) },
            Event::JobAdmit { job: 18, root: TaskId(19) },
            Event::JobDone { job: 20, root: TaskId(21) },
        ];
        for ev in evs {
            let (kind, p) = ev.encode();
            assert_eq!(Event::decode(kind, &p).as_ref(), Some(&ev), "{ev:?}");
        }
        // Unknown kinds and enum codes are rejected, not mangled.
        assert_eq!(Event::decode(200, &[0; 4]), None);
        assert_eq!(Event::decode(2, &[0, 0, 99, 0]), None);
    }
}
