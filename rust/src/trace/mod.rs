//! Scheduler event tracing (the paper's §6 future work: "analysis tools
//! based on tracing the scheduler at runtime, so as to check and refine
//! scheduling strategies").
//!
//! A bounded in-memory ring of timestamped events, cheap enough to leave
//! compiled in; recording is off unless enabled. Tests use traces to
//! assert *behavioural* properties (e.g. "every burst happens at the
//! bubble's bursting depth"), the CLI dumps them for humans.

pub mod analysis;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::task::TaskId;
use crate::topology::{CpuId, LevelId};

/// What happened.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// Task enqueued on a list.
    Enqueue { task: TaskId, list: LevelId },
    /// Thread dispatched on a CPU.
    Dispatch { task: TaskId, cpu: CpuId },
    /// Thread stopped running (yield/block/terminate).
    Stop { task: TaskId, cpu: CpuId, why: StopWhy },
    /// Bubble moved one level down towards a CPU (Figure 3 (b)-(c)).
    BubbleDown { bubble: TaskId, from: LevelId, to: LevelId },
    /// Bubble burst on a list (Figure 3 (d)).
    Burst { bubble: TaskId, list: LevelId, released: usize },
    /// Bubble regeneration began (§3.3.3).
    Regen { bubble: TaskId, why: RegenWhy },
    /// Regenerated bubble re-queued (closed again, moved up).
    RegenDone { bubble: TaskId, list: LevelId },
    /// A task was stolen from a list by a remote CPU's scheduler.
    Steal { task: TaskId, from: LevelId, by: CpuId },
    /// Barrier crossed by all participants.
    BarrierRelease { id: usize, waiters: usize },
}

/// Why a thread stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopWhy {
    Yield,
    Preempt,
    Block,
    Terminate,
    /// Re-entered its regenerating bubble (§4).
    BackInBubble,
}

/// Why a bubble regenerated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RegenWhy {
    /// An idle processor pulled it up to rebalance.
    Idle,
    /// Its time slice expired (gang scheduling).
    Timeslice,
}

/// A timestamped trace record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Record {
    /// Engine time (simulated cycles, or ns for the native executor).
    pub at: u64,
    pub event: Event,
}

/// Bounded trace buffer.
#[derive(Debug)]
pub struct Trace {
    enabled: AtomicBool,
    cap: usize,
    buf: Mutex<Vec<Record>>,
}

impl Default for Trace {
    fn default() -> Self {
        Trace::new(1 << 16)
    }
}

impl Trace {
    /// Create with the given capacity (oldest records dropped beyond it).
    pub fn new(cap: usize) -> Trace {
        Trace { enabled: AtomicBool::new(false), cap, buf: Mutex::new(Vec::new()) }
    }

    /// Turn recording on/off.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Release);
    }

    /// Is recording on?
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Acquire)
    }

    /// Record an event (no-op when disabled).
    pub fn emit(&self, at: u64, event: Event) {
        if !self.enabled() {
            return;
        }
        let mut buf = self.buf.lock().unwrap();
        if buf.len() == self.cap {
            buf.remove(0); // ring behaviour; cap is large, this is rare
        }
        buf.push(Record { at, event });
    }

    /// Copy of the recorded events.
    pub fn records(&self) -> Vec<Record> {
        self.buf.lock().unwrap().clone()
    }

    /// Number of recorded events.
    pub fn len(&self) -> usize {
        self.buf.lock().unwrap().len()
    }

    /// No events recorded?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all records.
    pub fn clear(&self) {
        self.buf.lock().unwrap().clear();
    }

    /// Human-readable dump.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        for r in self.records() {
            out.push_str(&format!("{:>12}  {:?}\n", r.at, r.event));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_by_default() {
        let t = Trace::default();
        t.emit(0, Event::Dispatch { task: TaskId(0), cpu: CpuId(0) });
        assert!(t.is_empty());
    }

    #[test]
    fn records_when_enabled() {
        let t = Trace::default();
        t.set_enabled(true);
        t.emit(5, Event::Burst { bubble: TaskId(1), list: LevelId(0), released: 4 });
        assert_eq!(t.len(), 1);
        assert_eq!(t.records()[0].at, 5);
        assert!(t.dump().contains("Burst"));
    }

    #[test]
    fn ring_drops_oldest() {
        let t = Trace::new(3);
        t.set_enabled(true);
        for i in 0..5 {
            t.emit(i, Event::Dispatch { task: TaskId(i as usize), cpu: CpuId(0) });
        }
        let r = t.records();
        assert_eq!(r.len(), 3);
        assert_eq!(r[0].at, 2);
        assert_eq!(r[2].at, 4);
    }
}
