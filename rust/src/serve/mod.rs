//! Multi-tenant job server: admit, isolate, and reallocate a stream of
//! jobs over one executor.
//!
//! The paper evaluates one application at a time; this module turns the
//! same machinery into a long-lived *server*: many concurrent jobs —
//! each an app shape plus a [`StructureMode`], a priority and a
//! [`DeadlineClass`] — are wrapped in their own bubble subtree under a
//! per-job root and multiplexed over one engine (simulator or native
//! executor). Cross-job processor reallocation is the `job-fair`
//! policy's business ([`crate::sched::JobFairScheduler`]); this module
//! provides the admission layer, the per-job bookkeeping, and the
//! arrival generator that drives thousands of short jobs through it.
//!
//! # Job lifecycle
//!
//! A job moves through four states, all recorded in the [`JobBook`]:
//!
//! 1. **Submitted** — the job's bubble subtree, member threads and
//!    regions exist, but nothing has been woken. Sim: built before the
//!    run, woken by the arrival-driver thread. Native: built and woken
//!    by a [`Submitter`] OS thread while the workers run.
//! 2. **Admitted** — the job root's first wake reached the scheduler.
//!    `arrived` is stamped, the admission order index assigned, an
//!    [`Event::JobAdmit`] emitted and `metrics.jobs_admitted` bumped.
//! 3. **Running** — some member was dispatched (`first_dispatch`
//!    stamped; `first_dispatch − arrived` is the admission latency).
//! 4. **Done** — every member terminated. `finished` is stamped, an
//!    [`Event::JobDone`] emitted and `metrics.jobs_completed` bumped.
//!    `finished − arrived` is the job's makespan in the mix.
//!
//! The tracking is a wrapper scheduler ([`JobTracker`]) around the
//! actual policy, so *every* registry policy can serve the job stream
//! and the lifecycle instrumentation is engine-independent: both
//! engines call `wake`/`pick`/`stop` the same way, and `sys.now()` is
//! simulated cycles on the simulator and wall nanoseconds natively.
//!
//! # Fairness knobs
//!
//! Reallocation policy lives in [`crate::sched::JobFairConfig`]:
//! `resize_hysteresis` (idle-pick streak before a job shrinks to free
//! room), `starve_hysteresis` (pick-miss streak of a strictly stricter
//! waiter before the weakest active job is squeezed), `timeslice`
//! (rotation between queued jobs), and `static_partition` (the
//! no-reallocation baseline: jobs are pinned round-robin to the root's
//! children and never moved — what a fixed per-tenant partition would
//! do). Per-job deadline classes are set at submission from
//! [`JobSpec::class`].
//!
//! Jobs deliberately contain **no cross-member barriers**: every
//! registry policy (including opportunists that scatter members) must
//! be able to drain an arbitrary job mix without coupling, which is
//! what the cross-job conformance matrix in `tests/policy_conformance`
//! relies on.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::apps::StructureMode;
use crate::config::SchedKind;
use crate::error::{Error, Result};
use crate::exec::Executor;
use crate::marcel::Marcel;
use crate::mem::{AllocPolicy, RegionId};
use crate::metrics::Metrics;
use crate::sched::factory::make_default;
use crate::sched::{
    DeadlineClass, JobFairConfig, JobFairScheduler, Scheduler, StopReason, System,
};
use crate::sim::{CostModel, Program, SimConfig, SimEngine};
use crate::task::{Prio, TaskId, PRIO_HIGH, PRIO_THREAD};
use crate::topology::{CpuId, DistanceModel, Topology};
use crate::trace::Event;
use crate::util::Rng;

/// Bytes of data each job member works on (attached per member, so
/// per-job footprints are visible to memory-aware policies and the
/// conformance matrix can check per-job conservation).
pub const JOB_REGION_BYTES: u64 = 256 << 10;

// ---------------------------------------------------------------- specs

/// What each member computes: the job's application shape. `Touch` is
/// the synthetic default (the spec's work/mem numbers as written);
/// `Conduction` and `Amr` are the paper's real-app profiles scaled to
/// job size — a uniform memory-bound stencil sweep, and a refinement
/// run whose members carry deliberately skewed work (1x..3x) so the
/// serving policy has to rebalance inside the job. Both stay
/// barrier-free (see the module docs on cross-member coupling).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum JobApp {
    #[default]
    Touch,
    Conduction,
    Amr,
}

impl JobApp {
    pub fn label(self) -> &'static str {
        match self {
            JobApp::Touch => "touch",
            JobApp::Conduction => "conduction",
            JobApp::Amr => "amr",
        }
    }

    /// Parse an app label (CLI / spool).
    pub fn parse(s: &str) -> Option<JobApp> {
        match s.to_ascii_lowercase().as_str() {
            "touch" => Some(JobApp::Touch),
            "conduction" => Some(JobApp::Conduction),
            "amr" => Some(JobApp::Amr),
            _ => None,
        }
    }

    /// Per-member sim compute profile: `(work, mem_fraction)` for
    /// member `k` of the job.
    pub fn member_profile(self, spec: &JobSpec, k: usize) -> (u64, f64) {
        match self {
            JobApp::Touch => (spec.work.max(1), spec.mem_fraction),
            // Stencil sweep: uniform work, firmly memory-bound.
            JobApp::Conduction => (spec.work.max(1), spec.mem_fraction.max(0.35)),
            // Refinement skew: member k carries 1x..3x the base work.
            JobApp::Amr => (spec.work.max(1) * (1 + k as u64 % 3), spec.mem_fraction),
        }
    }

    /// Per-member region-touch count on the native engine (the wall
    /// clock analogue of [`JobApp::member_profile`]).
    pub fn native_touches(self, touches: usize, k: usize) -> usize {
        match self {
            JobApp::Touch => touches.max(1),
            JobApp::Conduction => touches.max(2),
            JobApp::Amr => touches.max(1) * (1 + k % 3),
        }
    }
}

/// One job's shape: what the tenant submitted.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    /// How the job presents itself: flat members under the job root, or
    /// per-NUMA-node sub-bubbles (the paper's structure axis, per job).
    pub mode: StructureMode,
    /// What the members compute (synthetic touch loop or a real-app
    /// profile).
    pub app: JobApp,
    pub prio: Prio,
    pub class: DeadlineClass,
    /// Member threads.
    pub threads: usize,
    /// Compute items per member (sim) / touch cycles per member (native).
    pub cycles: usize,
    /// Simulated cycles per compute item.
    pub work: u64,
    /// Memory-bound fraction of each compute item.
    pub mem_fraction: f64,
    /// Region touches per cycle on the native engine.
    pub touches: usize,
}

impl JobSpec {
    /// Canonical short job (the bulk of the smoke stream).
    pub fn small(i: usize) -> JobSpec {
        JobSpec {
            name: format!("small{i}"),
            mode: StructureMode::Simple,
            app: JobApp::Touch,
            prio: PRIO_THREAD,
            class: DeadlineClass::Normal,
            threads: 1,
            cycles: 1,
            work: 20_000,
            mem_fraction: 0.3,
            touches: 1,
        }
    }

    /// Medium job: a couple of members, a couple of cycles.
    pub fn medium(i: usize) -> JobSpec {
        JobSpec {
            name: format!("medium{i}"),
            threads: 2,
            cycles: 2,
            work: 60_000,
            ..JobSpec::small(i)
        }
    }

    /// Large job: node-filling gang.
    pub fn large(i: usize) -> JobSpec {
        JobSpec {
            name: format!("large{i}"),
            threads: 4,
            cycles: 2,
            work: 150_000,
            ..JobSpec::small(i)
        }
    }

    /// Key identifying the job's *shape* (everything that determines
    /// its solo runtime) — the slowdown baseline is recorded per key.
    pub fn shape_key(&self) -> String {
        format!(
            "{}t{}c{}w{:.2}m:{}:{}",
            self.threads,
            self.cycles,
            self.work,
            self.mem_fraction,
            self.mode.label(),
            self.app.label()
        )
    }

    /// Serialise as one spool line (`key=value` pairs) for the
    /// `repro submit` → `repro serve` file queue.
    pub fn spool_line(&self) -> String {
        format!(
            "name={} mode={} app={} prio={} class={} threads={} cycles={} work={} mem={} touches={}",
            self.name,
            self.mode.label().to_lowercase(),
            self.app.label(),
            self.prio,
            self.class.label(),
            self.threads,
            self.cycles,
            self.work,
            self.mem_fraction,
            self.touches
        )
    }

    /// Parse one spool line. Unknown keys error; missing keys take the
    /// [`JobSpec::small`] defaults.
    pub fn parse_spool(line: &str) -> Result<JobSpec> {
        let mut spec = JobSpec::small(0);
        spec.name = "spool".into();
        for kv in line.split_whitespace() {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| Error::config(format!("spool: expected key=value, got {kv:?}")))?;
            let bad = |what: &str| Error::config(format!("spool: bad {what} {v:?}"));
            match k {
                "name" => spec.name = v.to_string(),
                "mode" => spec.mode = parse_mode(v).ok_or_else(|| bad("mode"))?,
                "app" => spec.app = JobApp::parse(v).ok_or_else(|| bad("app"))?,
                "prio" => spec.prio = v.parse().map_err(|_| bad("prio"))?,
                "class" => spec.class = DeadlineClass::parse(v).ok_or_else(|| bad("class"))?,
                "threads" => spec.threads = v.parse().map_err(|_| bad("threads"))?,
                "cycles" => spec.cycles = v.parse().map_err(|_| bad("cycles"))?,
                "work" => spec.work = v.parse().map_err(|_| bad("work"))?,
                "mem" => spec.mem_fraction = v.parse().map_err(|_| bad("mem"))?,
                "touches" => spec.touches = v.parse().map_err(|_| bad("touches"))?,
                other => return Err(Error::config(format!("spool: unknown key {other:?}"))),
            }
        }
        if spec.threads == 0 {
            return Err(Error::config("spool: threads must be >= 1"));
        }
        Ok(spec)
    }
}

/// Parse a structure-mode label (CLI / spool).
pub fn parse_mode(s: &str) -> Option<StructureMode> {
    match s.to_ascii_lowercase().as_str() {
        "simple" => Some(StructureMode::Simple),
        "bound" => Some(StructureMode::Bound),
        "bubbles" => Some(StructureMode::Bubbles),
        _ => None,
    }
}

/// Append a job spec to a spool file (`repro submit`).
pub fn append_spool(path: &str, spec: &JobSpec) -> Result<()> {
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    writeln!(f, "{}", spec.spool_line())?;
    Ok(())
}

/// Read every job spec from a spool file (`repro serve --queue`).
pub fn read_spool(path: &str) -> Result<Vec<JobSpec>> {
    let text = std::fs::read_to_string(path)?;
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(JobSpec::parse_spool)
        .collect()
}

// ------------------------------------------------------------- arrivals

/// One submission: wait `gap` (sim cycles / native ns) after the
/// previous one, then wake the job.
#[derive(Debug, Clone)]
pub struct Arrival {
    pub gap: u64,
    pub spec: JobSpec,
}

/// Bursty arrival generator: Poisson gaps with periodic burst phases
/// (a tight volley of back-to-back submissions), fully seeded.
#[derive(Debug, Clone)]
pub struct GenConfig {
    pub jobs: usize,
    pub seed: u64,
    /// Mean Poisson inter-arrival gap (sim cycles).
    pub mean_gap: u64,
    /// After this many Poisson arrivals, a burst phase starts...
    pub burst_every: usize,
    /// ...submitting this many jobs back to back...
    pub burst_len: usize,
    /// ...with this tiny fixed gap.
    pub burst_gap: u64,
    /// Fraction of jobs that carry a real-app profile instead of the
    /// synthetic touch loop. Zero (the default) draws nothing extra, so
    /// pre-existing seeded streams stay bit-identical.
    pub app_fraction: f64,
    /// The app those jobs carry; `None` draws conduction/amr 50:50.
    pub app: Option<JobApp>,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig {
            jobs: 200,
            seed: 0x5eed,
            mean_gap: 30_000,
            burst_every: 16,
            burst_len: 8,
            burst_gap: 1_000,
            app_fraction: 0.0,
            app: None,
        }
    }
}

/// Generate a seeded bursty job stream: ~70% small, ~20% medium, ~10%
/// large shapes; deadline classes ~20% latency / ~50% normal / ~30%
/// batch; ~30% of jobs present as per-node bubbles.
pub fn generate(cfg: &GenConfig) -> Vec<Arrival> {
    let mut rng = Rng::new(cfg.seed);
    let phase_len = cfg.burst_every + cfg.burst_len.max(1);
    let mut out = Vec::with_capacity(cfg.jobs);
    for i in 0..cfg.jobs {
        let in_burst = i % phase_len >= cfg.burst_every;
        let gap = if in_burst {
            cfg.burst_gap.max(1)
        } else {
            (rng.exp(cfg.mean_gap as f64) as u64).max(1)
        };
        let shape = rng.f64();
        let mut spec = if shape < 0.7 {
            JobSpec::small(i)
        } else if shape < 0.9 {
            JobSpec::medium(i)
        } else {
            JobSpec::large(i)
        };
        let class = rng.f64();
        spec.class = if class < 0.2 {
            DeadlineClass::Latency
        } else if class < 0.7 {
            DeadlineClass::Normal
        } else {
            DeadlineClass::Batch
        };
        if rng.chance(0.3) {
            spec.mode = StructureMode::Bubbles;
        }
        // Guarded behind the fraction: a zero-fraction config draws
        // nothing here, keeping older seeded streams bit-identical.
        if cfg.app_fraction > 0.0 && rng.chance(cfg.app_fraction) {
            spec.app = match cfg.app {
                Some(app) => app,
                None => {
                    if rng.chance(0.5) {
                        JobApp::Conduction
                    } else {
                        JobApp::Amr
                    }
                }
            };
        }
        out.push(Arrival { gap, spec });
    }
    out
}

// ------------------------------------------------------------- the book

/// Per-job lifecycle record (see the module docs for the state
/// machine). All times come from `sys.now()`.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub id: usize,
    pub spec: JobSpec,
    pub root: TaskId,
    pub members: Vec<TaskId>,
    pub regions: Vec<RegionId>,
    /// Members not yet terminated.
    remaining: usize,
    pub arrived: Option<u64>,
    pub first_dispatch: Option<u64>,
    pub finished: Option<u64>,
}

#[derive(Debug, Default)]
struct BookInner {
    jobs: Vec<JobRecord>,
    by_root: HashMap<TaskId, usize>,
    by_member: HashMap<TaskId, usize>,
    admission_order: Vec<usize>,
}

/// Shared job registry: one lock, engine-agnostic. The sim driver and
/// the native submitter threads register jobs; the [`JobTracker`]
/// stamps lifecycle times as the scheduler sees the events.
#[derive(Clone, Default)]
pub struct JobBook {
    inner: Arc<Mutex<BookInner>>,
}

impl JobBook {
    pub fn new() -> JobBook {
        JobBook::default()
    }

    /// Register a built (not yet woken) job. Returns its id.
    pub fn register(&self, spec: &JobSpec, built: &BuiltJob) -> usize {
        let mut b = self.inner.lock().unwrap();
        let id = b.jobs.len();
        b.by_root.insert(built.root, id);
        for &m in &built.members {
            b.by_member.insert(m, id);
        }
        b.jobs.push(JobRecord {
            id,
            spec: spec.clone(),
            root: built.root,
            members: built.members.clone(),
            regions: built.regions.clone(),
            remaining: built.members.len(),
            arrived: None,
            first_dispatch: None,
            finished: None,
        });
        id
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of every record.
    pub fn records(&self) -> Vec<JobRecord> {
        self.inner.lock().unwrap().jobs.clone()
    }

    /// Job ids in the order their roots were first woken.
    pub fn admission_order(&self) -> Vec<usize> {
        self.inner.lock().unwrap().admission_order.clone()
    }

    fn on_wake(&self, sys: &System, task: TaskId) {
        let mut b = self.inner.lock().unwrap();
        let Some(&id) = b.by_root.get(&task) else { return };
        if b.jobs[id].arrived.is_some() {
            return;
        }
        b.jobs[id].arrived = Some(sys.now());
        b.admission_order.push(id);
        Metrics::inc(&sys.metrics.jobs_admitted);
        sys.trace.emit(sys.now(), Event::JobAdmit { job: id as u64, root: task });
    }

    fn on_dispatch(&self, sys: &System, task: TaskId) {
        let mut b = self.inner.lock().unwrap();
        let Some(&id) = b.by_member.get(&task) else { return };
        if b.jobs[id].first_dispatch.is_none() {
            b.jobs[id].first_dispatch = Some(sys.now());
        }
    }

    fn on_terminate(&self, sys: &System, task: TaskId) {
        let mut b = self.inner.lock().unwrap();
        let Some(&id) = b.by_member.get(&task) else { return };
        let j = &mut b.jobs[id];
        if j.remaining == 0 {
            return; // double Terminate would be a scheduler bug
        }
        j.remaining -= 1;
        if j.remaining == 0 {
            j.finished = Some(sys.now());
            let root = j.root;
            Metrics::inc(&sys.metrics.jobs_completed);
            sys.trace.emit(sys.now(), Event::JobDone { job: id as u64, root });
        }
    }
}

// ---------------------------------------------------------- the tracker

/// Wrapper scheduler: forwards every call to the wrapped policy and
/// stamps job lifecycle events into the [`JobBook`] as they pass by.
/// This is what makes *any* registry policy servable: the admission
/// layer observes the scheduler protocol instead of requiring policy
/// cooperation.
pub struct JobTracker {
    inner: Arc<dyn Scheduler>,
    book: JobBook,
}

impl JobTracker {
    pub fn new(inner: Arc<dyn Scheduler>, book: JobBook) -> JobTracker {
        JobTracker { inner, book }
    }
}

impl Scheduler for JobTracker {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn wake(&self, sys: &System, task: TaskId) {
        self.book.on_wake(sys, task);
        self.inner.wake(sys, task);
    }

    fn pick(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
        let t = self.inner.pick(sys, cpu)?;
        self.book.on_dispatch(sys, t);
        Some(t)
    }

    fn stop(&self, sys: &System, cpu: CpuId, task: TaskId, why: StopReason) {
        self.inner.stop(sys, cpu, task, why);
        if why == StopReason::Terminate {
            self.book.on_terminate(sys, task);
        }
    }

    fn tick(&self, sys: &System, cpu: CpuId, task: TaskId, elapsed: u64) -> bool {
        self.inner.tick(sys, cpu, task, elapsed)
    }
}

// ----------------------------------------------------------- job builds

/// A job's constructed-but-unwoken subtree.
#[derive(Debug, Clone)]
pub struct BuiltJob {
    pub root: TaskId,
    pub members: Vec<TaskId>,
    pub regions: Vec<RegionId>,
}

/// Build a job's bubble subtree over a system: a per-job root bubble;
/// `Simple`/`Bound` put members directly in it, `Bubbles` groups them
/// into one sub-bubble per NUMA node. Each member gets an attached
/// region ([`JOB_REGION_BYTES`], first touch). Nothing is woken.
pub fn build_job(sys: &Arc<System>, spec: &JobSpec, id: usize) -> BuiltJob {
    let m = Marcel::with_system(sys);
    let root = m.bubble_init();
    let mut members = Vec::with_capacity(spec.threads);
    let mut regions = Vec::with_capacity(spec.threads);
    for k in 0..spec.threads {
        let t = m.create_dontsched_prio(format!("j{id}.{k}"), spec.prio);
        let r = sys.mem.alloc(JOB_REGION_BYTES, AllocPolicy::FirstTouch);
        m.attach_region(t, r);
        members.push(t);
        regions.push(r);
    }
    match spec.mode {
        StructureMode::Simple | StructureMode::Bound => {
            for &t in &members {
                m.bubble_inserttask(root, t);
            }
        }
        StructureMode::Bubbles => {
            let nodes = sys.topo.n_numa().max(1);
            let per = spec.threads.div_ceil(nodes).max(1);
            for chunk in members.chunks(per) {
                let sub = m.bubble_init();
                for &t in chunk {
                    m.bubble_inserttask(sub, t);
                }
                m.bubble_insertbubble(root, sub);
            }
        }
    }
    BuiltJob { root, members, regions }
}

/// The member program on the simulator: `cycles` compute items on the
/// member's own region, with work/mem set by the job's app profile for
/// member `k`. Deliberately barrier-free (see module docs).
fn member_program(spec: &JobSpec, k: usize, region: RegionId) -> Program {
    let (work, mem) = spec.app.member_profile(spec, k);
    let mut p = Program::new();
    for _ in 0..spec.cycles.max(1) {
        p = p.compute(work, mem, Some(region));
    }
    p
}

// -------------------------------------------------------------- serving

/// Which policy serves the mix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeConfig {
    pub kind: SchedKind,
    /// `job-fair` only: pin jobs round-robin and never reallocate (the
    /// static per-tenant partition baseline).
    pub static_partition: bool,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { kind: SchedKind::JobFair, static_partition: false, seed: 0x5eed }
    }
}

/// Write a serve run's event stream as Chrome trace-event JSON.
fn write_trace(trace: &crate::trace::Trace, topo: &Topology, path: &str, label: &str) {
    let recs = trace.drain();
    let json = crate::trace::export::chrome_json(&recs, topo.n_cpus(), label);
    std::fs::write(path, json).unwrap_or_else(|e| panic!("write trace {path}: {e}"));
}

/// Build the serving scheduler; for `job-fair` also return the concrete
/// handle (deadline classes are set through it at submission).
fn build_sched(cfg: &ServeConfig) -> (Arc<dyn Scheduler>, Option<Arc<JobFairScheduler>>) {
    if cfg.kind == SchedKind::JobFair {
        let jf = Arc::new(JobFairScheduler::new(JobFairConfig {
            static_partition: cfg.static_partition,
            ..JobFairConfig::default()
        }));
        (jf.clone(), Some(jf))
    } else {
        (make_default(cfg.kind), None)
    }
}

/// One served job's outcome.
#[derive(Debug, Clone)]
pub struct JobStats {
    pub id: usize,
    pub name: String,
    pub class: DeadlineClass,
    pub shape_key: String,
    pub arrived: u64,
    /// `finished − arrived` (sim cycles / native ns).
    pub makespan: u64,
    /// `first_dispatch − arrived`.
    pub admission_latency: u64,
    /// Local fraction of the job's own region touches (engine-side
    /// attribution, see [`crate::mem::RegionRegistry::note_locality`]).
    pub local_ratio: f64,
}

/// A full serve run's result.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    pub policy: String,
    pub jobs: Vec<JobStats>,
    /// Job ids in admission order.
    pub admission_order: Vec<usize>,
    /// Whole-mix makespan (sim cycles / native wall ns).
    pub mix_makespan: u64,
    /// Jobs that never finished (must be 0 on a successful run).
    pub lost: usize,
}

impl ServeOutcome {
    /// Per-job makespans in job-id order (determinism tests compare
    /// these vectors across seeded runs).
    pub fn makespans(&self) -> Vec<u64> {
        self.jobs.iter().map(|j| j.makespan).collect()
    }
}

/// Fold the book into a [`ServeOutcome`] once the engine drained.
fn collect(
    sys: &System,
    book: &JobBook,
    policy: String,
    mix_makespan: u64,
) -> Result<ServeOutcome> {
    let records = book.records();
    let lost = records.iter().filter(|r| r.finished.is_none()).count();
    if lost > 0 {
        return Err(Error::Sim(format!("serve: {lost} jobs lost (never finished)")));
    }
    let jobs = records
        .iter()
        .map(|r| {
            let arrived = r.arrived.expect("finished job must have arrived");
            let (mut loc, mut rem) = (0u64, 0u64);
            for &rg in &r.regions {
                let info = sys.mem.info(rg);
                loc += info.local_touches;
                rem += info.remote_touches;
            }
            JobStats {
                id: r.id,
                name: r.spec.name.clone(),
                class: r.spec.class,
                shape_key: r.spec.shape_key(),
                arrived,
                makespan: r.finished.unwrap().saturating_sub(arrived),
                admission_latency: r
                    .first_dispatch
                    .expect("finished job must have dispatched")
                    .saturating_sub(arrived),
                local_ratio: if loc + rem == 0 { 0.0 } else { loc as f64 / (loc + rem) as f64 },
            }
        })
        .collect();
    Ok(ServeOutcome {
        policy,
        jobs,
        admission_order: book.admission_order(),
        mix_makespan,
        lost,
    })
}

/// Serve an arrival stream on the **simulator**. Jobs are built up
/// front; a high-priority driver thread replays the arrival gaps and
/// wakes each job root in order, so admission timing is part of the
/// deterministic event stream — same seed + same stream ⇒ bit-identical
/// per-job makespans and admission order. `trace_out` writes the run's
/// event stream (job admits/dones included) as Chrome trace-event JSON.
pub fn run_sim(
    topo: &Topology,
    cfg: &ServeConfig,
    arrivals: &[Arrival],
    trace_out: Option<&str>,
) -> Result<ServeOutcome> {
    let (sched, jf) = build_sched(cfg);
    let book = JobBook::new();
    let tracker = Arc::new(JobTracker::new(sched, book.clone()));
    let sys = Arc::new(System::new(Arc::new(topo.clone())));
    let mut e = SimEngine::new(
        sys,
        tracker,
        CostModel::new(DistanceModel::default()),
        SimConfig { seed: cfg.seed, ..SimConfig::default() },
    );
    if trace_out.is_some() {
        e.sys.trace.set_enabled(true);
    }
    let mut driver = Program::new();
    for (i, a) in arrivals.iter().enumerate() {
        let built = build_job(&e.sys, &a.spec, i);
        if let Some(jf) = &jf {
            jf.set_class(built.root, a.spec.class);
        }
        for (k, (&t, &r)) in built.members.iter().zip(built.regions.iter()).enumerate() {
            e.set_program(t, member_program(&a.spec, k, r));
        }
        book.register(&a.spec, &built);
        driver = driver.compute(a.gap.max(1), 0.0, None).wake(built.root);
    }
    let d = e.add_thread("arrivals", PRIO_HIGH, driver);
    e.wake(d);
    let rep = e.run()?;
    let policy =
        format!("{}{}", cfg.kind.label(), if cfg.static_partition { "-static" } else { "" });
    if let Some(path) = trace_out {
        let label = format!("serve sim/{policy} on {}", topo.name());
        write_trace(&e.sys.trace, topo, path, &label);
    }
    collect(&e.sys, &book, policy, rep.total_time)
}

/// Serve an arrival stream on the **native executor**: `submitters` OS
/// threads stream jobs in through [`crate::exec::Submitter`] handles
/// while the workers drain them. Arrival gaps are honoured as
/// nanosecond sleeps (capped — the stream must outlive no one). With a
/// single submitter the admission order is deterministic; makespans are
/// wall time and are not.
pub fn run_native(
    topo: &Topology,
    cfg: &ServeConfig,
    arrivals: &[Arrival],
    submitters: usize,
    trace_out: Option<&str>,
) -> Result<ServeOutcome> {
    const MAX_GAP_NS: u64 = 200_000;
    let (sched, jf) = build_sched(cfg);
    let book = JobBook::new();
    let tracker = Arc::new(JobTracker::new(sched, book.clone()));
    let sys = Arc::new(System::new(Arc::new(topo.clone())));
    let mut ex = Executor::new(sys.clone(), tracker);
    if trace_out.is_some() {
        sys.trace.set_enabled(true);
    }
    let sub = ex.submitter();
    let n_subs = submitters.max(1);
    let handles: Vec<_> = (0..n_subs)
        .map(|s| {
            let sub = sub.clone();
            let jf = jf.clone();
            let book = book.clone();
            // Round-robin split keeps a single submitter's order equal
            // to the stream order (the determinism test relies on it).
            let mine: Vec<(usize, Arrival)> = arrivals
                .iter()
                .enumerate()
                .filter(|(i, _)| i % n_subs == s)
                .map(|(i, a)| (i, a.clone()))
                .collect();
            std::thread::spawn(move || {
                for (i, a) in mine {
                    std::thread::sleep(std::time::Duration::from_nanos(a.gap.min(MAX_GAP_NS)));
                    let sys = sub.system().clone();
                    let built = build_job(&sys, &a.spec, i);
                    if let Some(jf) = &jf {
                        jf.set_class(built.root, a.spec.class);
                    }
                    let cycles = a.spec.cycles.max(1);
                    for (k, (&t, &r)) in
                        built.members.iter().zip(built.regions.iter()).enumerate()
                    {
                        let touches = a.spec.app.native_touches(a.spec.touches, k);
                        sub.register(t, move |api| {
                            for _ in 0..cycles {
                                for _ in 0..touches {
                                    api.touch_region(r);
                                    api.yield_now();
                                }
                            }
                        });
                    }
                    book.register(&a.spec, &built);
                    sub.wake(built.root);
                }
                // The clone drops here, releasing its liveness latch.
            })
        })
        .collect();
    drop(sub);
    let rep = ex.run();
    for h in handles {
        h.join().map_err(|_| Error::Sim("serve: submitter thread panicked".into()))?;
    }
    let policy =
        format!("{}{}", cfg.kind.label(), if cfg.static_partition { "-static" } else { "" });
    if let Some(path) = trace_out {
        let label = format!("serve native/{policy} on {}", topo.name());
        write_trace(&sys.trace, topo, path, &label);
    }
    collect(&sys, &book, policy, rep.elapsed.as_nanos() as u64)
}

// ------------------------------------------------------------ quantiles

/// Exact quantile over a non-empty slice (nearest-rank on the sorted
/// copy). Panics on an empty slice — harness misuse.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
    v[idx]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    #[test]
    fn generator_is_seeded_and_bursty() {
        let cfg = GenConfig { jobs: 64, ..GenConfig::default() };
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), 64);
        assert_eq!(
            a.iter().map(|x| (x.gap, x.spec.shape_key())).collect::<Vec<_>>(),
            b.iter().map(|x| (x.gap, x.spec.shape_key())).collect::<Vec<_>>(),
            "same seed must generate the same stream"
        );
        // Burst phases exist: some gaps are the tight burst gap.
        assert!(a.iter().filter(|x| x.gap == cfg.burst_gap).count() >= cfg.burst_len);
        // All three deadline classes appear in a 64-job stream.
        for c in [DeadlineClass::Latency, DeadlineClass::Normal, DeadlineClass::Batch] {
            assert!(a.iter().any(|x| x.spec.class == c), "{c:?} missing");
        }
    }

    #[test]
    fn spool_roundtrip() {
        let mut s = JobSpec::large(3);
        s.class = DeadlineClass::Latency;
        s.mode = StructureMode::Bubbles;
        s.app = JobApp::Amr;
        let line = s.spool_line();
        let back = JobSpec::parse_spool(&line).unwrap();
        assert_eq!(back.name, s.name);
        assert_eq!(back.class, s.class);
        assert_eq!(back.mode, s.mode);
        assert_eq!(back.app, s.app);
        assert_eq!(back.threads, s.threads);
        assert_eq!(back.work, s.work);
        assert!(JobSpec::parse_spool("nonsense").is_err());
        assert!(JobSpec::parse_spool("threads=0").is_err());
        assert!(JobSpec::parse_spool("bogus=1").is_err());
        assert!(JobSpec::parse_spool("app=warp").is_err());
    }

    #[test]
    fn app_profiles_shape_members_and_streams() {
        // The amr profile skews work per member; conduction forces the
        // memory-bound floor; touch leaves the spec as written.
        let spec = JobSpec { app: JobApp::Amr, ..JobSpec::large(0) };
        assert_eq!(JobApp::Amr.member_profile(&spec, 0).0, spec.work);
        assert_eq!(JobApp::Amr.member_profile(&spec, 1).0, spec.work * 2);
        assert_eq!(JobApp::Amr.member_profile(&spec, 2).0, spec.work * 3);
        assert!(JobApp::Conduction.member_profile(&spec, 0).1 >= 0.35);
        assert_eq!(JobApp::Touch.member_profile(&spec, 1), (spec.work, spec.mem_fraction));
        // shape_key carries the app axis (solo runtime depends on it).
        assert!(spec.shape_key().ends_with(":amr"), "{}", spec.shape_key());
        // A zero app_fraction draws nothing: the stream matches the
        // pre-app generator bit for bit (all jobs stay Touch).
        let base = generate(&GenConfig { jobs: 48, ..GenConfig::default() });
        assert!(base.iter().all(|a| a.spec.app == JobApp::Touch));
        // Full-fraction single-app streams carry that app everywhere...
        let cfg = GenConfig {
            jobs: 48,
            app_fraction: 1.0,
            app: Some(JobApp::Conduction),
            ..GenConfig::default()
        };
        let all = generate(&cfg);
        assert!(all.iter().all(|a| a.spec.app == JobApp::Conduction));
        // ...and the first job's pre-app draws (gap, shape) are
        // untouched (later jobs see a shifted stream: the app draw
        // consumes the rng, which is fine — only the zero-fraction
        // config promises bit-compatibility).
        assert_eq!(base[0].gap, all[0].gap);
        assert_eq!(base[0].spec.threads, all[0].spec.threads);
        // The mixed stream draws both real apps.
        let mix =
            generate(&GenConfig { jobs: 48, app_fraction: 1.0, ..GenConfig::default() });
        assert!(mix.iter().any(|a| a.spec.app == JobApp::Conduction), "conduction missing");
        assert!(mix.iter().any(|a| a.spec.app == JobApp::Amr), "amr missing");
    }

    #[test]
    fn sim_serve_drains_real_app_jobs() {
        let topo = Topology::numa(2, 2);
        let cfg = GenConfig { jobs: 24, app_fraction: 1.0, ..GenConfig::default() };
        let arrivals = generate(&cfg);
        let out = run_sim(&topo, &ServeConfig::default(), &arrivals, None).unwrap();
        assert_eq!(out.lost, 0);
        assert_eq!(out.jobs.len(), 24);
    }

    #[test]
    fn sim_serve_completes_every_job_and_stamps_lifecycle() {
        let topo = Topology::numa(2, 2);
        let arrivals = generate(&GenConfig { jobs: 40, ..GenConfig::default() });
        let cfg = ServeConfig::default();
        let out = run_sim(&topo, &cfg, &arrivals, None).unwrap();
        assert_eq!(out.jobs.len(), 40);
        assert_eq!(out.lost, 0);
        assert_eq!(out.admission_order.len(), 40);
        for j in &out.jobs {
            assert!(j.makespan > 0, "job {} has zero makespan", j.id);
            assert!(j.makespan >= j.admission_latency, "job {}", j.id);
        }
        // The driver replays arrivals in stream order on one thread, so
        // admission order is exactly 0..n.
        assert_eq!(out.admission_order, (0..40).collect::<Vec<_>>());
    }

    #[test]
    fn serve_works_under_a_non_gang_policy_too() {
        // The tracker must not depend on job-fair cooperation.
        let topo = Topology::numa(2, 2);
        let arrivals = generate(&GenConfig { jobs: 24, ..GenConfig::default() });
        let cfg = ServeConfig { kind: SchedKind::Ss, ..ServeConfig::default() };
        let out = run_sim(&topo, &cfg, &arrivals, None).unwrap();
        assert_eq!(out.lost, 0);
        assert_eq!(out.jobs.len(), 24);
    }

    #[test]
    fn quantiles_are_exact_on_small_sets() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 3.0); // nearest rank rounds up here
    }
}
