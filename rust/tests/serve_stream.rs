//! Multi-tenant job-server stream tests:
//!
//! * **seeded determinism** — the same `--seed` and the same arrival
//!   trace produce bit-identical per-job makespans and admission order
//!   on the simulator, and an identical admission order on the native
//!   engine when a single submitter streams the jobs in;
//! * **concurrent-submit stress** — several OS threads submitting
//!   hundreds of short jobs while the workers drain them: nothing lost,
//!   nothing duplicated, the executor quiesces, and admission
//!   throughput stays above a generous smoke floor;
//! * **reallocation beats the static partition** (the tentpole claim,
//!   pinned): on the paper's numa(4,4), a mix whose round-robin static
//!   pinning lands both node-filling jobs on the *same* node is served
//!   strictly faster by cross-job reallocation, with the p99 slowdown
//!   bounded.

use bubbles::experiments::serve::run_leg;
use bubbles::serve::{
    generate, run_native, run_sim, Arrival, GenConfig, JobSpec, ServeConfig,
};
use bubbles::topology::Topology;

#[test]
fn seeded_sim_serve_is_bit_deterministic() {
    let topo = Topology::numa(2, 2);
    let arrivals = generate(&GenConfig { jobs: 48, seed: 7, ..GenConfig::default() });
    let cfg = ServeConfig { seed: 7, ..ServeConfig::default() };
    let a = run_sim(&topo, &cfg, &arrivals, None).unwrap();
    let b = run_sim(&topo, &cfg, &arrivals, None).unwrap();
    assert_eq!(a.makespans(), b.makespans(), "same seed + same trace ⇒ same makespans");
    assert_eq!(a.admission_order, b.admission_order);
    assert_eq!(a.mix_makespan, b.mix_makespan);
    assert_eq!(a.lost, 0);
    // A different engine seed only moves the jitter: the mix still
    // drains completely.
    let c = run_sim(&topo, &ServeConfig { seed: 8, ..ServeConfig::default() }, &arrivals, None)
        .unwrap();
    assert_eq!(c.lost, 0);
}

#[test]
fn native_single_submitter_admission_order_is_the_stream_order() {
    let topo = Topology::numa(2, 2);
    let arrivals =
        generate(&GenConfig { jobs: 40, seed: 11, mean_gap: 2_000, ..GenConfig::default() });
    let cfg = ServeConfig::default();
    let a = run_native(&topo, &cfg, &arrivals, 1, None).unwrap();
    let b = run_native(&topo, &cfg, &arrivals, 1, None).unwrap();
    // One submitter registers and wakes jobs sequentially in stream
    // order, so the admission order is exactly 0..n — on every run.
    // (Makespans are wall clock and deliberately not compared.)
    assert_eq!(a.admission_order, (0..40).collect::<Vec<_>>());
    assert_eq!(a.admission_order, b.admission_order);
    assert_eq!(a.lost, 0);
    assert_eq!(b.lost, 0);
}

#[test]
fn concurrent_submitters_stream_hundreds_of_jobs_without_loss() {
    let topo = Topology::numa(2, 2);
    let n = 300;
    let arrivals: Vec<Arrival> = (0..n)
        .map(|i| Arrival { gap: 1, spec: JobSpec { name: format!("s{i}"), ..JobSpec::small(i) } })
        .collect();
    let out = run_native(&topo, &ServeConfig::default(), &arrivals, 4, None).unwrap();
    // run_native returning at all means the executor quiesced and the
    // collector saw every job finished; pin the no-loss/no-dup claims
    // explicitly anyway.
    assert_eq!(out.lost, 0);
    assert_eq!(out.jobs.len(), n, "jobs lost under concurrent submission");
    let mut names: Vec<&str> = out.jobs.iter().map(|j| j.name.as_str()).collect();
    names.sort_unstable();
    names.dedup();
    assert_eq!(names.len(), n, "a job was duplicated or overwritten");
    let mut order = out.admission_order.clone();
    order.sort_unstable();
    order.dedup();
    assert_eq!(order.len(), n, "admission order lost or duplicated entries");
    // Smoke throughput floor: wildly generous (a failing run would be
    // one that took minutes to admit 300 trivial jobs).
    let arrived: Vec<u64> = out.jobs.iter().map(|j| j.arrived).collect();
    let span = arrived.iter().max().unwrap() - arrived.iter().min().unwrap();
    let per_sec = n as f64 / (span.max(1) as f64 / 1e9);
    assert!(per_sec > 5.0, "admission throughput collapsed: {per_sec:.1} jobs/s");
}

/// The adversarial mix for the pinned claim: eight jobs arriving back
/// to back, where the round-robin static partition (4 partitions on
/// numa(4,4)) pins job 0 and job 4 — the two node-filling ones — onto
/// the *same* node while the other nodes go idle after their tiny jobs.
fn adversarial_mix() -> Vec<Arrival> {
    (0..8)
        .map(|i| {
            let spec = if i % 4 == 0 {
                JobSpec {
                    name: format!("huge{i}"),
                    threads: 4,
                    cycles: 4,
                    work: 400_000,
                    ..JobSpec::small(i)
                }
            } else {
                JobSpec { name: format!("tiny{i}"), work: 30_000, ..JobSpec::small(i) }
            };
            Arrival { gap: 1, spec }
        })
        .collect()
}

#[test]
fn cross_job_reallocation_beats_the_static_partition() {
    let topo = Topology::numa(4, 4);
    let mix = adversarial_mix();
    let jf = ServeConfig::default();
    let st = ServeConfig { static_partition: true, ..ServeConfig::default() };
    let (jf_row, jf_out) = run_leg(&topo, &jf, &mix, false, 1, None).unwrap();
    let (_st_row, st_out) = run_leg(&topo, &st, &mix, false, 1, None).unwrap();
    assert_eq!(jf_out.lost, 0);
    assert_eq!(st_out.lost, 0);
    assert!(
        (jf_out.mix_makespan as f64) < 0.9 * st_out.mix_makespan as f64,
        "reallocation must beat the static partition on mix makespan: \
         job-fair {} vs static {}",
        jf_out.mix_makespan,
        st_out.mix_makespan
    );
    // Tail fairness stays bounded while reallocating: no job pays an
    // unbounded price for the mix win.
    assert!(
        jf_row.p99_slowdown < 50.0,
        "p99 slowdown unbounded under reallocation: {:.1}",
        jf_row.p99_slowdown
    );
}
