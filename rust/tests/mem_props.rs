//! Memory-subsystem properties (ISSUE-2 acceptance): footprint
//! conservation — at every step, the per-node bytes summed over root
//! tasks equal the total size of attached, homed regions — plus
//! dominant-node consistency, under randomised op sequences and under a
//! real memory-bound run.

use std::sync::Arc;

use bubbles::config::SchedKind;
use bubbles::marcel::Marcel;
use bubbles::mem::AllocPolicy;
use bubbles::sched::factory::make_default;
use bubbles::sched::System;
use bubbles::sim::{CostModel, SimConfig, SimEngine};
use bubbles::topology::{CpuId, DistanceModel, Topology};
use bubbles::util::proptest;

#[test]
fn footprint_conservation_under_random_ops() {
    proptest::check(0x6d656d, 30, |rng| {
        let topo = Topology::numa(4, 4);
        let n_cpus = topo.n_cpus();
        let sys = Arc::new(System::new(Arc::new(topo)));
        let m = Marcel::with_system(&sys);
        // A little bubble forest to aggregate into.
        let mut tasks = Vec::new();
        for b in 0..3 {
            let bubble = m.bubble_init();
            for k in 0..3 {
                let t = m.create_dontsched(format!("b{b}t{k}"));
                m.bubble_inserttask(bubble, t);
                tasks.push(t);
            }
        }
        for k in 0..3 {
            tasks.push(m.create_dontsched(format!("loose{k}")));
        }
        let mut regions = Vec::new();
        for step in 0..200 {
            match rng.below(5) {
                0 => {
                    let policy = match rng.below(3) {
                        0 => AllocPolicy::FirstTouch,
                        1 => AllocPolicy::RoundRobin,
                        _ => AllocPolicy::Fixed(rng.below(4) as usize),
                    };
                    let size = 1 + rng.below(1 << 20);
                    regions.push(sys.mem.alloc(size, policy));
                }
                1 if !regions.is_empty() => {
                    let r = *rng.choose(&regions);
                    let t = *rng.choose(&tasks);
                    sys.mem.attach(&sys.tasks, t, r);
                }
                2 if !regions.is_empty() => {
                    let r = *rng.choose(&regions);
                    let cpu = CpuId(rng.below(n_cpus as u64) as usize);
                    sys.mem.touch(&sys.tasks, &sys.topo, r, cpu);
                }
                3 if !regions.is_empty() => {
                    let r = *rng.choose(&regions);
                    sys.mem.mark_next_touch(r);
                }
                4 => {
                    let t = *rng.choose(&tasks);
                    sys.mem.mark_task_regions_next_touch(t);
                }
                _ => {}
            }
            assert!(
                sys.mem.conserved(&sys.tasks),
                "conservation broken at step {step}"
            );
            // Dominant node must agree with the raw counters.
            for &t in &tasks {
                let v = sys.mem.footprint.of(t);
                match sys.mem.dominant_node(t) {
                    None => assert!(v.iter().all(|&b| b == 0)),
                    Some(n) => {
                        let max = *v.iter().max().unwrap();
                        assert!(v[n] == max && max > 0, "dominant {n} of {v:?}");
                    }
                }
            }
        }
    });
}

#[test]
fn memaware_run_conserves_footprint_and_counts_migrations() {
    let topo = Topology::numa(4, 4);
    let sys = Arc::new(System::new(Arc::new(topo)));
    let sched = make_default(SchedKind::Memaware);
    let mut e = SimEngine::new(
        sys,
        sched,
        CostModel::new(DistanceModel::default()),
        SimConfig::default(),
    );
    let p = bubbles::apps::conduction::HeatParams {
        threads: 24,
        cycles: 8,
        work: 400_000,
        mem_fraction: 0.35,
    };
    bubbles::apps::conduction::build(&mut e, bubbles::apps::StructureMode::Simple, &p);
    e.run().expect("memaware conduction");
    assert!(e.sys.mem.conserved(&e.sys.tasks), "footprint leaked during the run");
    // Migration counters must agree: bytes move only when regions do.
    use std::sync::atomic::Ordering;
    let migs = e.sys.metrics.mem_migrations.load(Ordering::Relaxed);
    let bytes = e.sys.metrics.migrated_bytes.load(Ordering::Relaxed);
    assert_eq!(migs == 0, bytes == 0, "migrations {migs} vs bytes {bytes}");
}
