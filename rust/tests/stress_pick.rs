//! Multi-worker stress: N OS threads hammering one `System` through the
//! real scheduler pick/stop paths (ROADMAP open item — exercises the
//! `core::pick` two-pass retry accounting under genuine contention).
//!
//! Properties pinned:
//! * **task conservation** — every woken thread is picked exactly once
//!   and ends Terminated (the two-pass search may retry, but a task can
//!   never be lost or handed to two CPUs);
//! * **retry accounting** — `metrics.search_retries` is reported for
//!   each policy (the single-list `ss` policy maximises hint races).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use bubbles::config::SchedKind;
use bubbles::sched::factory::make_default;
use bubbles::sched::{StopReason, System};
use bubbles::task::{TaskId, TaskState, PRIO_THREAD};
use bubbles::topology::{CpuId, Topology};

/// Wake `n_tasks` threads, then let one OS worker per CPU pick+terminate
/// until everything drained. Returns the search_retries counter.
fn hammer(kind: SchedKind, n_tasks: usize) -> u64 {
    let sys = Arc::new(System::new(Arc::new(Topology::numa(4, 4))));
    let sched = make_default(kind);
    for i in 0..n_tasks {
        let t = sys.tasks.new_thread(format!("t{i}"), PRIO_THREAD);
        sched.wake(&sys, t);
    }
    let picked: Arc<Vec<AtomicUsize>> =
        Arc::new((0..n_tasks).map(|_| AtomicUsize::new(0)).collect());
    let done = Arc::new(AtomicUsize::new(0));
    let n_cpus = sys.topo.n_cpus();
    let mut joins = Vec::with_capacity(n_cpus);
    for w in 0..n_cpus {
        let sys = sys.clone();
        let sched = sched.clone();
        let picked = picked.clone();
        let done = done.clone();
        joins.push(std::thread::spawn(move || {
            let cpu = CpuId(w);
            while done.load(Ordering::SeqCst) < n_tasks {
                match sched.pick(&sys, cpu) {
                    Some(t) => {
                        picked[t.0].fetch_add(1, Ordering::SeqCst);
                        sched.stop(&sys, cpu, t, StopReason::Terminate);
                        done.fetch_add(1, Ordering::SeqCst);
                    }
                    None => std::thread::yield_now(),
                }
            }
        }));
    }
    for j in joins {
        j.join().expect("worker panicked");
    }
    for (i, c) in picked.iter().enumerate() {
        assert_eq!(
            c.load(Ordering::SeqCst),
            1,
            "task t{i} picked {} times under {}",
            c.load(Ordering::SeqCst),
            kind.label()
        );
    }
    for i in 0..n_tasks {
        assert_eq!(sys.tasks.state(TaskId(i)), TaskState::Terminated, "t{i}");
    }
    let retries = sys.metrics.search_retries.load(Ordering::Relaxed);
    println!(
        "{}: {} tasks over {} workers, search_retries = {}",
        kind.label(),
        n_tasks,
        n_cpus,
        retries
    );
    retries
}

#[test]
fn ss_conserves_tasks_under_contention() {
    // One global list: the worst case for pass-2 races.
    hammer(SchedKind::Ss, 2000);
}

#[test]
fn afs_conserves_tasks_under_contention() {
    hammer(SchedKind::Afs, 2000);
}

#[test]
fn lds_conserves_tasks_under_contention() {
    hammer(SchedKind::Lds, 2000);
}

#[test]
fn memaware_conserves_tasks_under_contention() {
    hammer(SchedKind::Memaware, 2000);
}
