//! Multi-worker stress: N OS threads hammering one `System` through the
//! real scheduler pick/stop paths (ROADMAP open item — exercises the
//! `core::pick` two-pass retry accounting under genuine contention).
//!
//! Properties pinned:
//! * **task conservation** — every woken thread is picked exactly once
//!   and ends Terminated (the two-pass search may retry, but a task can
//!   never be lost or handed to two CPUs);
//! * **retry accounting** — `metrics.search_retries` is reported for
//!   each policy (the single-list `ss` policy maximises hint races);
//! * **scope stability** — the adaptive policy under a bursty
//!   native-executor workload records its scope-switch count and keeps
//!   migrations bounded (no ping-pong between scopes).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use bubbles::config::SchedKind;
use bubbles::sched::factory::make_default;
use bubbles::sched::{AdaptiveConfig, AdaptiveScheduler, Scheduler, StopReason, System};
use bubbles::task::{TaskId, TaskState, PRIO_THREAD};
use bubbles::topology::{CpuId, Topology};

/// Wake `n_tasks` threads, then let one OS worker per CPU pick+terminate
/// until everything drained. Returns the search_retries counter.
fn hammer(kind: SchedKind, n_tasks: usize) -> u64 {
    let sys = Arc::new(System::new(Arc::new(Topology::numa(4, 4))));
    let sched = make_default(kind);
    for i in 0..n_tasks {
        let t = sys.tasks.new_thread(format!("t{i}"), PRIO_THREAD);
        sched.wake(&sys, t);
    }
    let picked: Arc<Vec<AtomicUsize>> =
        Arc::new((0..n_tasks).map(|_| AtomicUsize::new(0)).collect());
    let done = Arc::new(AtomicUsize::new(0));
    let n_cpus = sys.topo.n_cpus();
    let mut joins = Vec::with_capacity(n_cpus);
    for w in 0..n_cpus {
        let sys = sys.clone();
        let sched = sched.clone();
        let picked = picked.clone();
        let done = done.clone();
        joins.push(std::thread::spawn(move || {
            let cpu = CpuId(w);
            while done.load(Ordering::SeqCst) < n_tasks {
                match sched.pick(&sys, cpu) {
                    Some(t) => {
                        picked[t.0].fetch_add(1, Ordering::SeqCst);
                        sched.stop(&sys, cpu, t, StopReason::Terminate);
                        done.fetch_add(1, Ordering::SeqCst);
                    }
                    None => std::thread::yield_now(),
                }
            }
        }));
    }
    for j in joins {
        j.join().expect("worker panicked");
    }
    for (i, c) in picked.iter().enumerate() {
        assert_eq!(
            c.load(Ordering::SeqCst),
            1,
            "task t{i} picked {} times under {}",
            c.load(Ordering::SeqCst),
            kind.label()
        );
    }
    for i in 0..n_tasks {
        assert_eq!(sys.tasks.state(TaskId(i)), TaskState::Terminated, "t{i}");
    }
    let retries = sys.metrics.search_retries.load(Ordering::Relaxed);
    println!(
        "{}: {} tasks over {} workers, search_retries = {}",
        kind.label(),
        n_tasks,
        n_cpus,
        retries
    );
    retries
}

#[test]
fn ss_conserves_tasks_under_contention() {
    // One global list: the worst case for pass-2 races.
    hammer(SchedKind::Ss, 2000);
}

#[test]
fn afs_conserves_tasks_under_contention() {
    hammer(SchedKind::Afs, 2000);
}

#[test]
fn lds_conserves_tasks_under_contention() {
    hammer(SchedKind::Lds, 2000);
}

#[test]
fn memaware_conserves_tasks_under_contention() {
    hammer(SchedKind::Memaware, 2000);
}

#[test]
fn adaptive_conserves_tasks_under_contention() {
    hammer(SchedKind::Adaptive, 2000);
}

/// Bursty arrival under real OS workers: a producer wakes waves of
/// tasks with quiet gaps between; per-CPU adaptive controllers widen
/// during the droughts and narrow during the bursts. Conservation must
/// hold, the scope-switch count is recorded, and both migrations and
/// scope switches stay bounded — a controller ping-ponging between
/// scopes would blow the switch budget.
#[test]
fn adaptive_bursty_scope_switches_bounded() {
    const BURSTS: usize = 20;
    const PER_BURST: usize = 100;
    let total = BURSTS * PER_BURST;

    let sys = Arc::new(System::new(Arc::new(Topology::numa(4, 4))));
    let sched_impl = Arc::new(AdaptiveScheduler::new(AdaptiveConfig::default()));
    let sched: Arc<dyn Scheduler> = sched_impl.clone();
    let n_cpus = sys.topo.n_cpus();
    let depth = sys.topo.covering(CpuId(0)).len();

    let picked: Arc<Vec<AtomicUsize>> =
        Arc::new((0..total).map(|_| AtomicUsize::new(0)).collect());
    let done = Arc::new(AtomicUsize::new(0));

    let producer = {
        let sys = sys.clone();
        let sched = sched.clone();
        std::thread::spawn(move || {
            for b in 0..BURSTS {
                for i in 0..PER_BURST {
                    let t = sys.tasks.new_thread(format!("b{b}t{i}"), PRIO_THREAD);
                    sched.wake(&sys, t);
                }
                // The drought between bursts: workers spin dry and the
                // controllers widen towards machine scope.
                std::thread::sleep(std::time::Duration::from_micros(300));
            }
        })
    };
    let mut joins = Vec::with_capacity(n_cpus);
    for w in 0..n_cpus {
        let sys = sys.clone();
        let sched = sched.clone();
        let picked = picked.clone();
        let done = done.clone();
        joins.push(std::thread::spawn(move || {
            let cpu = CpuId(w);
            while done.load(Ordering::SeqCst) < total {
                match sched.pick(&sys, cpu) {
                    Some(t) => {
                        picked[t.0].fetch_add(1, Ordering::SeqCst);
                        sched.stop(&sys, cpu, t, StopReason::Terminate);
                        done.fetch_add(1, Ordering::SeqCst);
                    }
                    None => std::thread::yield_now(),
                }
            }
        }));
    }
    producer.join().expect("producer panicked");
    for j in joins {
        j.join().expect("worker panicked");
    }

    // Conservation: picked exactly once, all terminated.
    for (i, c) in picked.iter().enumerate() {
        let n = c.load(Ordering::SeqCst);
        assert_eq!(n, 1, "task t{i} picked {n} times");
    }
    for i in 0..total {
        assert_eq!(sys.tasks.state(TaskId(i)), TaskState::Terminated, "t{i}");
    }

    // A terminated-on-first-pick task migrates at most once, so the
    // migration count is bounded by the task count; cross-node moves
    // are a subset.
    let migrations = sys.metrics.migrations.load(Ordering::Relaxed);
    let cross = sys.metrics.cross_node_migrations.load(Ordering::Relaxed);
    assert!(migrations <= total as u64, "migrations {migrations} > tasks {total}");
    assert!(cross <= migrations, "cross-node {cross} > migrations {migrations}");

    // Scope stability: per drought a CPU can widen at most depth-1
    // levels and per burst narrow at most depth-1 back; anything far
    // beyond that budget means the controller is ping-ponging.
    let switches = sched_impl.scope_switches();
    let budget = (BURSTS * n_cpus * 2 * (depth - 1)) as u64;
    println!(
        "adaptive bursty: {total} tasks, scope_switches = {switches} (budget {budget}), \
         migrations = {migrations}, cross_node = {cross}"
    );
    assert!(switches <= budget, "scope ping-pong: {switches} switches > budget {budget}");
}
