//! Golden-file tests for `/sys` topology detection: canned sysfs
//! snapshots written to a temp dir and parsed through
//! `Topology::detect_from_sysfs`, covering SLIT normalisation, offline
//! CPUs, non-contiguous and memory-only nodes, SMT laptops, and the
//! documented smp-N fallback when `/sys` is missing entirely.

use std::path::PathBuf;

use bubbles::topology::{CpuId, Topology};

/// A canned sysfs tree under a unique temp dir. Paths are relative to
/// the snapshot root, exactly as the parser expects them under `/`.
struct Snapshot {
    root: PathBuf,
}

impl Snapshot {
    fn new(tag: &str) -> Snapshot {
        let root =
            std::env::temp_dir().join(format!("bubbles-detect-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(root.join("sys/devices/system/cpu")).unwrap();
        std::fs::create_dir_all(root.join("sys/devices/system/node")).unwrap();
        Snapshot { root }
    }

    fn write(&self, rel: &str, content: &str) -> &Snapshot {
        let p = self.root.join(rel);
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(p, content).unwrap();
        self
    }

    /// One online CPU's physical identity files.
    fn cpu(&self, os: usize, package: usize, core: usize) -> &Snapshot {
        let dir = format!("sys/devices/system/cpu/cpu{os}/topology");
        self.write(&format!("{dir}/package_id"), &format!("{package}\n"));
        self.write(&format!("{dir}/core_id"), &format!("{core}\n"))
    }

    fn parse(&self) -> Topology {
        Topology::detect_from_sysfs(&self.root).expect("snapshot must parse")
    }
}

impl Drop for Snapshot {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.root);
    }
}

#[test]
fn two_node_machine_normalises_slit_distances() {
    let s = Snapshot::new("two-node");
    s.write("sys/devices/system/cpu/online", "0-3\n");
    s.cpu(0, 0, 0).cpu(1, 0, 1).cpu(2, 1, 0).cpu(3, 1, 1);
    s.write("sys/devices/system/node/node0/cpulist", "0-1\n");
    s.write("sys/devices/system/node/node1/cpulist", "2-3\n");
    // ACPI SLIT convention: local 10, remote 21 → normalised 1.0 / 2.1.
    s.write("sys/devices/system/node/node0/distance", "10 21\n");
    s.write("sys/devices/system/node/node1/distance", "21 10\n");
    let t = s.parse();
    assert_eq!(t.name(), "detect");
    assert_eq!(t.n_cpus(), 4);
    assert_eq!(t.n_numa(), 2);
    // Machine → NumaNode → Core, one CPU per core: no SMT level.
    assert_eq!(t.depth(), 3);
    assert_eq!(t.os_cpus().unwrap(), &[0, 1, 2, 3]);
    let m = t.numa_matrix().expect("SLIT matrix must survive parsing");
    assert_eq!(m.len(), 2);
    assert_eq!(m[0][0], 1.0);
    assert_eq!(m[1][1], 1.0);
    assert!((m[0][1] - 2.1).abs() < 1e-9, "got {}", m[0][1]);
    assert!((m[1][0] - 2.1).abs() < 1e-9, "got {}", m[1][0]);
}

#[test]
fn offline_cpus_and_non_contiguous_nodes_are_handled() {
    // CPUs 1 and 3 are offline; the machine has nodes 0, 1, 2 where
    // node1 is memory-only (empty cpulist). Distance rows still carry
    // one column per *existing* node — the parser must select the
    // CPU-bearing columns by position, not by node id.
    let s = Snapshot::new("holes");
    s.write("sys/devices/system/cpu/online", "0,2,4-5\n");
    s.cpu(0, 0, 0).cpu(2, 0, 1).cpu(4, 1, 0).cpu(5, 1, 1);
    s.write("sys/devices/system/node/node0/cpulist", "0,2\n");
    s.write("sys/devices/system/node/node1/cpulist", "\n");
    s.write("sys/devices/system/node/node2/cpulist", "4-5\n");
    s.write("sys/devices/system/node/node0/distance", "10 15 20\n");
    s.write("sys/devices/system/node/node1/distance", "15 10 15\n");
    s.write("sys/devices/system/node/node2/distance", "20 15 10\n");
    let t = s.parse();
    assert_eq!(t.n_cpus(), 4, "offline CPUs must be absent");
    assert_eq!(t.n_numa(), 2, "memory-only nodes hold no scheduling level");
    // vCPUs are renumbered contiguously; the OS ids survive in the map.
    assert_eq!(t.os_cpus().unwrap(), &[0, 2, 4, 5]);
    let m = t.numa_matrix().expect("matrix for the two CPU-bearing nodes");
    assert_eq!(m.len(), 2);
    assert!((m[0][1] - 2.0).abs() < 1e-9, "node0→node2 column picked: {}", m[0][1]);
    assert!((m[1][0] - 2.0).abs() < 1e-9, "node2→node0 column picked: {}", m[1][0]);
}

#[test]
fn single_node_smt_laptop_gets_an_smt_level() {
    let s = Snapshot::new("laptop");
    s.write("sys/devices/system/cpu/online", "0-3\n");
    // Two physical cores, two hardware threads each.
    s.cpu(0, 0, 0).cpu(1, 0, 0).cpu(2, 0, 1).cpu(3, 0, 1);
    s.write("sys/devices/system/node/node0/cpulist", "0-3\n");
    s.write("sys/devices/system/node/node0/distance", "10\n");
    let t = s.parse();
    assert_eq!(t.n_cpus(), 4);
    assert_eq!(t.n_numa(), 1);
    // Machine → NumaNode → Core → Smt.
    assert_eq!(t.depth(), 4);
    assert_eq!(t.smt_sibling(CpuId(0)), Some(CpuId(1)));
    assert_eq!(t.smt_sibling(CpuId(2)), Some(CpuId(3)));
    assert_eq!(t.os_cpus().unwrap(), &[0, 1, 2, 3]);
}

#[test]
fn malformed_snapshots_error_but_detect_still_falls_back() {
    // No sys/ tree at all → an error the caller can see…
    let empty =
        std::env::temp_dir().join(format!("bubbles-detect-empty-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&empty);
    std::fs::create_dir_all(&empty).unwrap();
    assert!(Topology::detect_from_sysfs(&empty).is_err());
    let _ = std::fs::remove_dir_all(&empty);
    // …and a garbage online list errors instead of mis-parsing.
    let s = Snapshot::new("garbage");
    s.write("sys/devices/system/cpu/online", "zero-four\n");
    assert!(Topology::detect_from_sysfs(&s.root).is_err());
    // The public entry point never fails: it degrades to the
    // documented smp-N fallback with an identity OS-CPU map.
    let t = Topology::detect();
    assert!(t.n_cpus() >= 1);
    assert_eq!(t.os_cpus().map(|m| m.len()), Some(t.n_cpus()));
}

#[test]
fn native_workers_pin_or_fall_back_on_a_detected_machine() {
    // End-to-end: run the native memcmp harness on a canned detected
    // topology. Every worker must either pin to its mapped OS CPU or
    // count a pin failure — the per-worker fallback, exercised for
    // real here because the snapshot maps vCPUs to OS CPUs this host
    // may not have.
    use bubbles::apps::conduction::HeatParams;
    use bubbles::apps::StructureMode;
    use bubbles::config::SchedKind;
    use bubbles::experiments::memcmp;
    let s = Snapshot::new("native");
    s.write("sys/devices/system/cpu/online", "0-3\n");
    s.cpu(0, 0, 0).cpu(1, 0, 1).cpu(2, 1, 0).cpu(3, 1, 1);
    s.write("sys/devices/system/node/node0/cpulist", "0-1\n");
    s.write("sys/devices/system/node/node1/cpulist", "2-3\n");
    let topo = s.parse();
    let p = HeatParams { threads: 6, cycles: 2, work: 0, mem_fraction: 0.0 };
    let c = memcmp::run_native(
        &topo,
        &p,
        &[SchedKind::Afs],
        2,
        bubbles::mem::AllocPolicy::FirstTouch,
        true, // arena-backed regions: touches walk real mmap'd bytes
        &[StructureMode::Simple],
        None,
    );
    let row = c.get("afs");
    assert!(row.makespan > 0);
    assert_eq!(
        row.workers_pinned + row.pin_failures,
        topo.n_cpus() as u64,
        "every worker must pin or count a failure (pinned {}, failed {})",
        row.workers_pinned,
        row.pin_failures
    );
}
