//! Policy-registry round trips: every registered policy must be
//! reachable from every entry point — config TOML, the CLI `--sched`
//! path, and the `repro schedulers` listing — and the listing must stay
//! in sync with the registry (names, aliases, count).

use bubbles::cli;
use bubbles::config::{ExperimentConfig, SchedKind};
use bubbles::sched::factory;

fn argv(s: &str) -> Vec<String> {
    s.split_whitespace().map(|x| x.to_string()).collect()
}

#[test]
fn every_policy_constructible_from_config_toml() {
    for e in factory::registry() {
        for name in std::iter::once(&e.name).chain(e.aliases.iter()) {
            let toml = format!("[sched]\nkind = \"{name}\"");
            let cfg = ExperimentConfig::from_toml(&toml)
                .unwrap_or_else(|err| panic!("`{name}` rejected by config: {err}"));
            assert_eq!(cfg.sched.kind, e.kind, "`{name}` resolved to the wrong kind");
            let sched = factory::make(&cfg.sched);
            assert_eq!(sched.name(), e.name, "name() must match the registry");
        }
    }
}

#[test]
fn cli_sched_flag_parses_every_policy_and_alias() {
    // `--sched <name>` goes through SchedKind::parse, which must accept
    // every canonical name and alias, case-insensitively.
    for e in factory::registry() {
        assert_eq!(SchedKind::parse(e.name), Some(e.kind), "{}", e.name);
        assert_eq!(SchedKind::parse(&e.name.to_uppercase()), Some(e.kind), "{}", e.name);
        for a in e.aliases {
            assert_eq!(SchedKind::parse(a), Some(e.kind), "alias {a}");
        }
    }
    assert_eq!(SchedKind::parse("definitely-not-a-policy"), None);
}

#[test]
fn cli_analyze_runs_registry_policies_end_to_end() {
    // Full `--sched` path on a small machine for a paper policy and the
    // memory-aware one (the zoo covers the rest in-sim).
    for sched in ["afs", "memaware"] {
        let out = cli::run(&argv(&format!("analyze --machine numa-2x2 --sched {sched}")))
            .unwrap_or_else(|err| panic!("analyze --sched {sched}: {err}"));
        assert!(out.contains(sched), "{out}");
        assert!(out.contains("makespan"), "{out}");
    }
}

#[test]
fn schedulers_listing_stays_in_sync_with_registry() {
    let out = cli::run(&argv("schedulers")).unwrap();
    assert!(
        out.contains(&format!("({})", factory::registry().len())),
        "listing must report the registry size:\n{out}"
    );
    for e in factory::registry() {
        assert!(out.contains(e.name), "{} missing from listing:\n{out}", e.name);
        assert!(out.contains(e.summary), "summary of {} missing", e.name);
        for a in e.aliases {
            assert!(out.contains(a), "alias {a} missing from listing");
        }
    }
    // SchedKind::all and the registry must cover each other 1:1.
    assert_eq!(SchedKind::all().len(), factory::registry().len());
    for kind in SchedKind::all() {
        assert!(
            factory::registry().iter().any(|e| e.kind == *kind),
            "{kind:?} unregistered"
        );
    }
}
