//! Cross-thread behaviour of the sharded trace rings, and the
//! end-to-end observability pipeline on both engines: concurrent
//! writers drain exactly once in time order, drain is well-defined
//! while recording continues, the Chrome exporter emits one complete
//! span per executed segment, the latency histograms bucket correctly,
//! and the native engine's `sys.now()` is wall-clock (monotone,
//! non-zero).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use bubbles::apps::conduction::{self, HeatParams};
use bubbles::apps::{engine_with, StructureMode};
use bubbles::config::SchedKind;
use bubbles::exec::Executor;
use bubbles::mem::AllocPolicy;
use bubbles::metrics::Histogram;
use bubbles::rq::owner;
use bubbles::sched::factory::make_default;
use bubbles::sched::System;
use bubbles::sim::SimConfig;
use bubbles::task::TaskId;
use bubbles::topology::{CpuId, Topology};
use bubbles::trace::{export, Event, Record, Trace};
use bubbles::util::json;

/// Stream ordering invariant: the merged stream is sorted by
/// (timestamp, global sequence).
fn assert_time_ordered(recs: &[Record]) {
    for w in recs.windows(2) {
        assert!(
            (w[0].at, w[0].seq) <= (w[1].at, w[1].seq),
            "merged stream out of order: ({}, {}) then ({}, {})",
            w[0].at,
            w[0].seq,
            w[1].at,
            w[1].seq
        );
    }
}

#[test]
fn concurrent_writers_drain_exactly_once_in_time_order() {
    // 4 writers, each under its own CPU's owner identity, well under
    // shard capacity: every record must come out exactly once even
    // though drains run concurrently with the writers.
    const WRITERS: usize = 4;
    const PER_WRITER: usize = 2000;
    let trace = Arc::new(Trace::for_cpus(WRITERS, 4096));
    trace.set_enabled(true);
    let running = Arc::new(AtomicBool::new(true));
    let mut joins = Vec::new();
    for w in 0..WRITERS {
        let trace = trace.clone();
        joins.push(std::thread::spawn(move || {
            owner::set_current_cpu(Some(CpuId(w)));
            for i in 0..PER_WRITER {
                // Unique payload per record: task id encodes (writer, i).
                let task = TaskId(w * PER_WRITER + i);
                trace.emit(i as u64, Event::Dispatch { task, cpu: CpuId(w) });
            }
            owner::set_current_cpu(None);
        }));
    }
    // Drain concurrently while the writers run (the drain-while-
    // recording satellite: a mid-run drain is well-defined, not UB).
    let mut collected: Vec<Record> = Vec::new();
    while running.load(Ordering::Relaxed) {
        let batch = trace.drain();
        assert_time_ordered(&batch);
        collected.extend(batch);
        if joins.iter().all(|j| j.is_finished()) {
            running.store(false, Ordering::Relaxed);
        }
    }
    for j in joins {
        j.join().unwrap();
    }
    collected.extend(trace.drain());
    assert_eq!(trace.dropped(), 0, "capacity was never exceeded");
    assert_eq!(collected.len(), WRITERS * PER_WRITER);
    // Exactly once: every (writer, i) payload appears once.
    let mut seen = vec![false; WRITERS * PER_WRITER];
    for r in &collected {
        match r.event {
            Event::Dispatch { task, cpu } => {
                assert!(!seen[task.0], "record {} drained twice", task.0);
                seen[task.0] = true;
                // Shard attribution followed the owner identity.
                assert_eq!(r.cpu, Some(cpu));
            }
            ref e => panic!("unexpected event {e:?}"),
        }
    }
    assert!(seen.iter().all(|&s| s), "some records were lost");
    // A second drain on a quiet trace yields nothing.
    assert!(trace.drain().is_empty());
}

#[test]
fn drain_while_recording_accounts_every_record() {
    // Tiny rings so writers lap the reader: drained + dropped must
    // still equal emitted — no record is double-counted or silently
    // lost even when set_enabled/drain race with concurrent emits.
    const WRITERS: usize = 2;
    const PER_WRITER: usize = 20_000;
    let trace = Arc::new(Trace::for_cpus(WRITERS, 256));
    trace.set_enabled(true);
    let mut joins = Vec::new();
    for w in 0..WRITERS {
        let trace = trace.clone();
        joins.push(std::thread::spawn(move || {
            owner::set_current_cpu(Some(CpuId(w)));
            for i in 0..PER_WRITER {
                trace.emit(i as u64, Event::WorkerPark { cpu: CpuId(w) });
            }
            owner::set_current_cpu(None);
        }));
    }
    let mut drained = 0usize;
    while !joins.iter().all(|j| j.is_finished()) {
        let batch = trace.drain();
        assert_time_ordered(&batch);
        drained += batch.len();
    }
    for j in joins {
        j.join().unwrap();
    }
    drained += trace.drain().len();
    assert_eq!(
        drained as u64 + trace.dropped(),
        (WRITERS * PER_WRITER) as u64,
        "drained + dropped must account for every emit"
    );
    assert!(drained > 0, "something must have come out");
}

#[test]
fn emit_stays_flat_at_capacity() {
    // Regression guard for the old O(n) eviction: emitting far past
    // capacity must stay O(1) amortized per record. 400k emits into a
    // 1k-slot shard completes in well under the generous bound even on
    // a loaded CI runner; the old linear eviction would be quadratic.
    let trace = Trace::new(1 << 10);
    trace.set_enabled(true);
    let t0 = std::time::Instant::now();
    for i in 0..400_000u64 {
        trace.emit(i, Event::WorkerUnpark { cpu: CpuId(0) });
    }
    let elapsed = t0.elapsed();
    assert!(
        elapsed < std::time::Duration::from_secs(5),
        "400k emits at capacity took {elapsed:?} — eviction is not O(1)"
    );
    assert_eq!(trace.len(), 1 << 10, "ring holds exactly its capacity");
    assert!(trace.dropped() > 0, "lapping must be accounted");
}

/// One traced conduction run on the simulator; returns (records, topo).
fn traced_sim_run() -> (Vec<Record>, Topology) {
    let topo = Topology::numa(2, 2);
    let mut e = engine_with(&topo, make_default(SchedKind::Afs), SimConfig::default());
    e.sys.trace.set_enabled(true);
    let p = HeatParams { threads: 6, cycles: 3, work: 100_000, mem_fraction: 0.3 };
    conduction::build(&mut e, StructureMode::Simple, &p);
    e.run().expect("sim run");
    (e.sys.trace.drain(), topo)
}

/// One traced conduction run on the native executor; returns (records,
/// topo, final sys.now()).
fn traced_native_run() -> (Vec<Record>, Topology, u64) {
    let topo = Topology::numa(2, 2);
    let sys = Arc::new(System::new(Arc::new(topo.clone())));
    sys.trace.set_enabled(true);
    let mut ex = Executor::new(sys.clone(), make_default(SchedKind::Afs));
    let p = HeatParams { threads: 6, cycles: 3, work: 0, mem_fraction: 0.0 };
    conduction::build_native(&mut ex, StructureMode::Simple, &p, AllocPolicy::FirstTouch, 2);
    ex.run();
    let now = sys.now();
    (sys.trace.drain(), topo, now)
}

fn dispatch_count(recs: &[Record]) -> usize {
    recs.iter().filter(|r| matches!(r.event, Event::Dispatch { .. })).count()
}

#[test]
fn chrome_export_is_valid_json_with_complete_spans_sim() {
    let (recs, topo) = traced_sim_run();
    assert!(!recs.is_empty());
    assert_time_ordered(&recs);
    let out = export::chrome_json(&recs, topo.n_cpus(), "sim test");
    json::validate(&out).unwrap_or_else(|e| panic!("invalid Chrome JSON: {e}"));
    assert!(out.contains("\"traceEvents\""));
    // Every Dispatch yields exactly one complete X span (closed by its
    // Stop, by a successor Dispatch, or at the end of the stream).
    let x_count = out.matches("\"ph\":\"X\"").count();
    assert_eq!(x_count, dispatch_count(&recs), "one span per executed segment");
}

#[test]
fn chrome_export_is_valid_json_with_complete_spans_native() {
    let (recs, topo, _) = traced_native_run();
    assert!(!recs.is_empty());
    assert_time_ordered(&recs);
    let out = export::chrome_json(&recs, topo.n_cpus(), "native test");
    json::validate(&out).unwrap_or_else(|e| panic!("invalid Chrome JSON: {e}"));
    let x_count = out.matches("\"ph\":\"X\"").count();
    assert_eq!(x_count, dispatch_count(&recs), "one span per executed segment");
    assert!(x_count > 0, "the native run must have executed segments");
}

#[test]
fn native_timestamps_are_wall_clock_monotone_nonzero() {
    let (recs, _, now) = traced_native_run();
    assert!(now > 0, "anchored sys.now() must be non-zero after the run");
    for r in &recs {
        assert!(r.at > 0, "native event carries a zero timestamp: {r:?}");
    }
    // The merged stream is non-decreasing in wall time, and the run
    // spans a real interval (not one collapsed instant).
    assert_time_ordered(&recs);
    let t_min = recs.iter().map(|r| r.at).min().unwrap();
    let t_max = recs.iter().map(|r| r.at).max().unwrap();
    assert!(t_max > t_min, "wall clock never advanced: {t_min}..{t_max}");
}

#[test]
fn histogram_buckets_a_known_synthetic_stream() {
    // Log-bucket boundaries under a known stream: bucket 0 is {0},
    // bucket i is [2^(i-1), 2^i).
    let h = Histogram::from_samples([0, 1, 1, 2, 3, 4, 7, 8, 1000, 1024]);
    assert_eq!(h.total(), 10);
    assert_eq!(h.count(0), 1, "0");
    assert_eq!(h.count(1), 2, "two 1s");
    assert_eq!(h.count(2), 2, "2 and 3");
    assert_eq!(h.count(3), 2, "4 and 7");
    assert_eq!(h.count(4), 1, "8");
    assert_eq!(h.count(10), 1, "1000 in [512, 1024)");
    assert_eq!(h.count(11), 1, "1024 in [1024, 2048)");
    // Percentiles report the owning bucket's exclusive upper bound.
    assert_eq!(h.percentile(100.0), 2048);
    assert!(h.percentile(50.0) <= 8);
}
