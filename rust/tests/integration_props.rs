//! Cross-module property tests: scheduler invariants under randomized
//! workloads, topologies and policies (our proptest-lite harness).

use std::sync::Arc;

use bubbles::config::SchedKind;
use bubbles::marcel::Marcel;
use bubbles::sched::factory::make_default;
use bubbles::sched::{BubbleConfig, BubbleScheduler, Scheduler, StopReason, System};
use bubbles::task::{BurstLevel, TaskId, TaskState, PRIO_THREAD};
use bubbles::topology::{CpuId, Topology};
use bubbles::util::proptest::check;
use bubbles::util::Rng;

fn random_topo(rng: &mut Rng) -> Topology {
    match rng.below(4) {
        0 => Topology::smp(rng.range(1, 9)),
        1 => Topology::numa(rng.range(2, 5), rng.range(1, 5)),
        2 => Topology::xeon_2x_ht(),
        _ => Topology::deep(),
    }
}

/// No task is ever lost and no task is ever dispatched twice
/// concurrently, for any scheduler, topology, and chaotic schedule.
#[test]
fn no_loss_no_double_dispatch_any_scheduler() {
    check(0xabc1, 40, |rng| {
        let topo = random_topo(rng);
        let n_cpus = topo.n_cpus();
        let sys = Arc::new(System::new(Arc::new(topo)));
        let kind = *rng.choose(&[
            SchedKind::Bubble,
            SchedKind::Ss,
            SchedKind::Gss,
            SchedKind::Tss,
            SchedKind::Afs,
            SchedKind::Lds,
            SchedKind::Cafs,
            SchedKind::Hafs,
            SchedKind::Bound,
        ]);
        let sched = make_default(kind);
        let n = rng.range(1, 30);
        let mut remaining = std::collections::HashSet::new();
        for i in 0..n {
            let t = sys.tasks.new_thread(format!("t{i}"), PRIO_THREAD);
            sched.wake(&sys, t);
            remaining.insert(t);
        }
        let mut running: Vec<Option<TaskId>> = vec![None; n_cpus];
        let mut fuel = 50 * n * n_cpus + 200;
        while !remaining.is_empty() && fuel > 0 {
            fuel -= 1;
            let cpu = rng.range(0, n_cpus);
            match running[cpu] {
                Some(t) => {
                    let why = if rng.chance(0.4) { StopReason::Yield } else { StopReason::Terminate };
                    sched.stop(&sys, CpuId(cpu), t, why);
                    if why == StopReason::Terminate {
                        remaining.remove(&t);
                    }
                    running[cpu] = None;
                }
                None => {
                    if let Some(t) = sched.pick(&sys, CpuId(cpu)) {
                        // Double-dispatch check: nobody else may hold t.
                        assert!(
                            !running.iter().flatten().any(|&r| r == t),
                            "{kind:?}: double dispatch of {t}"
                        );
                        assert_eq!(sys.tasks.state(t), TaskState::Running { cpu: CpuId(cpu) });
                        running[cpu] = Some(t);
                    }
                }
            }
        }
        // Drain leftovers.
        for (cpu, slot) in running.iter().enumerate() {
            if let Some(t) = slot {
                sched.stop(&sys, CpuId(cpu), *t, StopReason::Terminate);
                remaining.remove(t);
            }
        }
        let mut extra_fuel = 50 * n * n_cpus + 200;
        while !remaining.is_empty() && extra_fuel > 0 {
            extra_fuel -= 1;
            let cpu = rng.range(0, n_cpus);
            if let Some(t) = sched.pick(&sys, CpuId(cpu)) {
                sched.stop(&sys, CpuId(cpu), t, StopReason::Terminate);
                remaining.remove(&t);
            }
        }
        assert!(remaining.is_empty(), "{kind:?} lost {} tasks", remaining.len());
    });
}

/// Bubble scheduler: bursts always happen at a depth <= the bursting
/// level, and every released thread lands on a list covering the
/// releasing area.
#[test]
fn bursts_respect_bursting_level() {
    check(0xabc2, 30, |rng| {
        let topo = random_topo(rng);
        let n_cpus = topo.n_cpus();
        let max_depth = topo.depth() - 1;
        let burst_depth = rng.range(0, max_depth + 1);
        let sys = Arc::new(System::new(Arc::new(topo)));
        sys.trace.set_enabled(true);
        let sched = BubbleScheduler::new(BubbleConfig {
            default_burst: BurstLevel::Depth(burst_depth),
            ..BubbleConfig::default()
        });
        let m = Marcel::with_system(&sys);
        let b = m.bubble_init();
        for i in 0..rng.range(1, 6) {
            let t = m.create_dontsched(format!("t{i}"));
            m.bubble_inserttask(b, t);
        }
        sched.wake(&sys, b);
        // Drain from random CPUs.
        let mut fuel = 200;
        while fuel > 0 {
            fuel -= 1;
            let cpu = CpuId(rng.range(0, n_cpus));
            match sched.pick(&sys, cpu) {
                Some(t) => sched.stop(&sys, cpu, t, StopReason::Terminate),
                None => break,
            }
        }
        for r in sys.trace.records() {
            if let bubbles::trace::Event::Burst { list, .. } = r.event {
                let d = sys.topo.node(list).depth;
                assert!(
                    d <= burst_depth,
                    "burst at depth {d} exceeds bursting level {burst_depth}"
                );
            }
        }
    });
}

/// After any run, every thread is Terminated and every list is empty —
/// nothing leaks onto runqueues.
#[test]
fn runqueues_drain_clean() {
    check(0xabc3, 30, |rng| {
        let topo = random_topo(rng);
        let n_cpus = topo.n_cpus();
        let sys = Arc::new(System::new(Arc::new(topo)));
        let sched = BubbleScheduler::new(BubbleConfig {
            regen_hysteresis: rng.range(0, 2) as u64 * 1_000_000,
            ..BubbleConfig::default()
        });
        let m = Marcel::with_system(&sys);
        // Random forest.
        for g in 0..rng.range(1, 4) {
            let b = m.bubble_init();
            for k in 0..rng.range(1, 4) {
                let t = m.create_dontsched(format!("g{g}k{k}"));
                m.bubble_inserttask(b, t);
            }
            sched.wake(&sys, b);
        }
        let mut fuel = 2000;
        loop {
            fuel -= 1;
            assert!(fuel > 0, "did not drain");
            let cpu = CpuId(rng.range(0, n_cpus));
            match sched.pick(&sys, cpu) {
                Some(t) => {
                    if rng.chance(0.25) {
                        sched.stop(&sys, cpu, t, StopReason::Yield);
                    } else {
                        sched.stop(&sys, cpu, t, StopReason::Terminate);
                    }
                }
                None => {
                    if sys.tasks.live_threads() == 0 {
                        break;
                    }
                }
            }
        }
        assert_eq!(sys.rq.total_queued(), 0, "runqueues must be empty");
        let snap = sys.rq.snapshot();
        assert!(snap.is_empty(), "leaked: {snap:?}");
    });
}

/// Priorities are never inverted by the pick: the dispatched thread's
/// priority is >= every ready thread visible from that CPU at pick
/// time (single-threaded check).
#[test]
fn no_priority_inversion_single_threaded() {
    check(0xabc4, 30, |rng| {
        let topo = random_topo(rng);
        let n_cpus = topo.n_cpus();
        let sys = Arc::new(System::new(Arc::new(topo)));
        let sched = BubbleScheduler::new(BubbleConfig::default());
        let n = rng.range(2, 12);
        for i in 0..n {
            let t = sys.tasks.new_thread(format!("t{i}"), rng.range(0, 5) as i32);
            sched.wake(&sys, t);
        }
        let cpu = CpuId(rng.range(0, n_cpus));
        if let Some(t) = sched.pick(&sys, cpu) {
            let got = sys.tasks.prio(t);
            // Any remaining ready task visible from this cpu must not
            // outrank the dispatched one.
            for &l in sys.topo.covering(cpu) {
                let max = sys.rq.peek_max(l);
                if max != i32::MIN {
                    assert!(max <= got, "inversion: left prio {max} > got {got}");
                }
            }
        }
    });
}
