//! Integration: native executor (fibers + workers) under every
//! scheduler, including stress and failure-order cases.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use bubbles::config::SchedKind;
use bubbles::exec::Executor;
use bubbles::marcel::Marcel;
use bubbles::sched::baselines::make_default;
use bubbles::sched::{BubbleConfig, BubbleScheduler, System};
use bubbles::topology::Topology;

fn system(topo: Topology) -> Arc<System> {
    Arc::new(System::new(Arc::new(topo)))
}

#[test]
fn native_run_under_each_baseline() {
    for kind in [SchedKind::Ss, SchedKind::Afs, SchedKind::Hafs, SchedKind::Bound] {
        let sys = system(Topology::smp(4));
        let sched = make_default(kind);
        let mut ex = Executor::new(sys, sched);
        let count = Arc::new(AtomicU64::new(0));
        for i in 0..12 {
            let c = count.clone();
            ex.spawn(format!("t{i}"), move |api| {
                c.fetch_add(1, Ordering::SeqCst);
                api.yield_now();
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        ex.run();
        assert_eq!(count.load(Ordering::SeqCst), 24, "{kind:?}");
    }
}

#[test]
fn native_stress_many_fibers() {
    let sys = system(Topology::smp(8));
    let sched = Arc::new(BubbleScheduler::new(BubbleConfig::default()));
    let mut ex = Executor::new(sys, sched);
    let count = Arc::new(AtomicU64::new(0));
    for i in 0..200 {
        let c = count.clone();
        ex.spawn(format!("t{i}"), move |api| {
            for _ in 0..10 {
                c.fetch_add(1, Ordering::SeqCst);
                api.yield_now();
            }
        });
    }
    let rep = ex.run();
    assert_eq!(rep.threads, 200);
    assert_eq!(count.load(Ordering::SeqCst), 2000);
}

#[test]
fn native_nested_bubble_hierarchy() {
    let sys = system(Topology::numa(2, 2));
    let sched = Arc::new(BubbleScheduler::new(BubbleConfig::default()));
    let m = Marcel::over(sys.clone(), sched.clone());
    let mut ex = Executor::new(sys.clone(), sched);
    let count = Arc::new(AtomicU64::new(0));
    let root = m.bubble_init();
    for g in 0..2 {
        let b = m.bubble_init();
        for k in 0..4 {
            let t = m.create_dontsched(format!("g{g}k{k}"));
            m.bubble_inserttask(b, t);
            let c = count.clone();
            ex.register(t, move |api| {
                c.fetch_add(1, Ordering::SeqCst);
                api.yield_now();
            });
        }
        m.bubble_insertbubble(root, b);
    }
    m.wake_up_bubble(root);
    ex.run();
    assert_eq!(count.load(Ordering::SeqCst), 8);
    assert_eq!(
        sys.tasks.state(root),
        bubbles::task::TaskState::Terminated,
        "root bubble must terminate with its threads"
    );
}

#[test]
fn native_repeated_barriers_with_uneven_work() {
    let sys = system(Topology::smp(4));
    let sched = Arc::new(BubbleScheduler::new(BubbleConfig::default()));
    let mut ex = Executor::new(sys, sched);
    let bar = ex.alloc_barrier(6);
    let max_phase_gap = Arc::new(AtomicU64::new(0));
    let phase = Arc::new(AtomicU64::new(0));
    for i in 0..6 {
        let p = phase.clone();
        let gap = max_phase_gap.clone();
        ex.spawn(format!("t{i}"), move |api| {
            for round in 0..8u64 {
                // Uneven spin to shuffle arrival order.
                for _ in 0..(i * 1000) {
                    std::hint::black_box(round);
                }
                let before = p.fetch_add(1, Ordering::SeqCst) + 1;
                // All arrivals of round r land in (6r, 6(r+1)].
                let lo = 6 * round;
                assert!(before > lo, "barrier round bled: {before} <= {lo}");
                gap.fetch_max(before - lo, Ordering::SeqCst);
                api.barrier(bar);
            }
        });
    }
    ex.run();
    assert_eq!(phase.load(Ordering::SeqCst), 48);
    assert!(max_phase_gap.load(Ordering::SeqCst) <= 6);
}

#[test]
fn native_memaware_beats_afs_on_locality() {
    // ISSUE-4 acceptance: the sim pin (`memaware` strictly above `afs`
    // on local-access ratio, numa(4,4)) mirrored on real green
    // threads. Regions are round-robin homed across the nodes, so the
    // memory-aware wake can place each thread on its data's node from
    // the start, while AFS places and steals memory-blind. Smoke-sized
    // and heavily oversubscribed so the ordering is robust to OS
    // scheduling noise.
    use bubbles::apps::conduction::HeatParams;
    use bubbles::apps::StructureMode;
    use bubbles::experiments::memcmp;
    let topo = Topology::numa(4, 4);
    let p = HeatParams { threads: 24, cycles: 8, work: 0, mem_fraction: 0.0 };
    let c = memcmp::run_native(
        &topo,
        &p,
        &[SchedKind::Memaware, SchedKind::Afs],
        4,
        bubbles::mem::AllocPolicy::RoundRobin,
        false,
        &[StructureMode::Simple],
        None,
    );
    let ma = c.get("memaware");
    let afs = c.get("afs");
    assert!(ma.makespan > 0 && afs.makespan > 0);
    assert!(
        ma.local_ratio > 0.0 && afs.local_ratio > 0.0,
        "touches must be attributed on the native engine: memaware {:.3}, afs {:.3}",
        ma.local_ratio,
        afs.local_ratio
    );
    assert!(
        ma.local_ratio > afs.local_ratio,
        "native memaware {:.3} must beat afs {:.3} on locality",
        ma.local_ratio,
        afs.local_ratio
    );
}

#[test]
fn native_bubble_structure_keeps_accesses_at_least_as_local_as_loose_threads() {
    // ISSUE-5 acceptance: the paper's structured-vs-flat comparison on
    // the native engine. The same oversubscribed conduction workload
    // under the bubble scheduler, once as loose green threads and once
    // grouped into one bubble per NUMA node: the bubble structure must
    // not lose locality against the flat shape (first-touch homing, so
    // a thread that stays in its node bubble keeps its data local,
    // while loose threads get rebalanced memory-blind).
    use bubbles::apps::conduction::HeatParams;
    use bubbles::apps::StructureMode;
    use bubbles::experiments::memcmp;
    let topo = Topology::numa(4, 4);
    let p = HeatParams { threads: 24, cycles: 8, work: 0, mem_fraction: 0.0 };
    let c = memcmp::run_native(
        &topo,
        &p,
        &[SchedKind::Bubble],
        4,
        bubbles::mem::AllocPolicy::FirstTouch,
        false,
        &[StructureMode::Simple, StructureMode::Bubbles],
        None,
    );
    let simple = c.get_structured("bubble", StructureMode::Simple);
    let bubbles = c.get_structured("bubble", StructureMode::Bubbles);
    assert!(simple.makespan > 0 && bubbles.makespan > 0);
    assert!(
        simple.local_ratio > 0.0 && bubbles.local_ratio > 0.0,
        "touches must be attributed: simple {:.3}, bubbles {:.3}",
        simple.local_ratio,
        bubbles.local_ratio
    );
    assert!(
        bubbles.local_ratio >= simple.local_ratio,
        "bubble structure {:.3} must not lose locality vs loose threads {:.3}",
        bubbles.local_ratio,
        simple.local_ratio
    );
}

#[test]
fn native_backoff_is_bounded_when_work_is_queued_but_unpickable() {
    // A moldable gang shrinks onto one NUMA node; the other node's
    // workers then repeatedly see queued work they may not pick. They
    // must park on the executor condvar under the capped exponential
    // backoff (counted in exec_backoffs) instead of busy-polling a
    // fixed 200µs sleep — the metric bounds the idle-path traffic.
    use bubbles::sched::{MoldableConfig, MoldableGangScheduler};
    let sys = system(Topology::numa(2, 2));
    let sched = Arc::new(MoldableGangScheduler::new(MoldableConfig {
        resize_hysteresis: 1,
        ..Default::default()
    }));
    let m = Marcel::with_system(&sys);
    let mut ex = Executor::new(sys.clone(), sched.clone());
    let b = m.bubble_init();
    let done = Arc::new(AtomicU64::new(0));
    for k in 0..2 {
        let t = m.create_dontsched(format!("k{k}"));
        m.bubble_inserttask(b, t);
        let d = done.clone();
        ex.register(t, move |api| {
            for i in 0..200u64 {
                for _ in 0..2_000 {
                    std::hint::black_box(i);
                }
                api.yield_now();
            }
            d.fetch_add(1, Ordering::SeqCst);
        });
    }
    use bubbles::sched::Scheduler;
    sched.wake(&sys, b);
    ex.run();
    assert_eq!(done.load(Ordering::SeqCst), 2, "gang must finish");
    let backoffs = sys.metrics.exec_backoffs.load(Ordering::SeqCst);
    assert!(
        backoffs < 50_000,
        "busy-polling regression: {backoffs} queued-but-unpickable backoff waits"
    );
}

#[test]
fn native_gang_scheduler_runs_gangs() {
    let sys = system(Topology::smp(4));
    let sched = make_default(SchedKind::Gang);
    let m = Marcel::with_system(&sys);
    let mut ex = Executor::new(sys.clone(), sched.clone());
    let count = Arc::new(AtomicU64::new(0));
    for g in 0..3 {
        let b = m.bubble_init();
        for k in 0..2 {
            let t = m.create_dontsched(format!("g{g}k{k}"));
            m.bubble_inserttask(b, t);
            let c = count.clone();
            ex.register(t, move |api| {
                c.fetch_add(1, Ordering::SeqCst);
                api.yield_now();
            });
        }
        sched.wake(&sys, b);
    }
    ex.run();
    assert_eq!(count.load(Ordering::SeqCst), 6);
}
