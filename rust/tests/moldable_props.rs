//! Property tests for moldable gang scheduling: random shrink/expand
//! sequences interleaved with scheduling traffic must never lose or
//! duplicate a gang member, never break the disjointness of the active
//! CPU sets, and never leave a runnable gang without CPUs.

use std::collections::BTreeMap;
use std::sync::Arc;

use bubbles::marcel::Marcel;
use bubbles::sched::{MoldableConfig, MoldableGangScheduler, Scheduler, StopReason, System};
use bubbles::task::{TaskId, TaskState};
use bubbles::topology::{CpuId, Topology};
use bubbles::util::proptest::check;
use bubbles::util::Rng;

fn machines() -> Vec<Topology> {
    vec![Topology::smp(4), Topology::numa(2, 2), Topology::numa(4, 4), Topology::asym()]
}

/// Where each member of each gang currently is, for conservation
/// checks: a member must be in exactly one place.
fn member_census(sys: &System, gangs: &BTreeMap<TaskId, Vec<TaskId>>) {
    // No member may appear on more than one runqueue (or twice on one).
    let mut queued: BTreeMap<TaskId, usize> = BTreeMap::new();
    for (_list, task, _prio) in sys.rq.snapshot() {
        *queued.entry(task).or_insert(0) += 1;
    }
    for (&gang, members) in gangs {
        for &m in members {
            let state = sys.tasks.state(m);
            let on_queue = queued.get(&m).copied().unwrap_or(0);
            match state {
                TaskState::Ready { .. } => {
                    assert_eq!(on_queue, 1, "gang {gang}: member {m} Ready but queued {on_queue}×")
                }
                _ => assert_eq!(
                    on_queue, 0,
                    "gang {gang}: member {m} is {state:?} but sits on a runqueue"
                ),
            }
        }
    }
}

/// Active components are pairwise disjoint and every runnable gang is
/// somewhere it can make progress (owns CPUs, or is queued/running
/// towards them).
fn placement_invariants(
    sys: &System,
    s: &MoldableGangScheduler,
    gangs: &BTreeMap<TaskId, Vec<TaskId>>,
) {
    let assignments = s.assignments();
    for (i, &(ga, ca)) in assignments.iter().enumerate() {
        let na = sys.topo.node(ca);
        assert!(na.cpu_count >= 1, "gang {ga} assigned an empty component");
        for &(gb, cb) in assignments.iter().skip(i + 1) {
            let nb = sys.topo.node(cb);
            let overlap = na.cpu_first < nb.cpu_first + nb.cpu_count
                && nb.cpu_first < na.cpu_first + na.cpu_count;
            assert!(!overlap, "gangs {ga} and {gb} own overlapping CPU sets {ca:?}/{cb:?}");
        }
    }
    // A gang with runnable members must never be dropped: if it is not
    // active, its runnable members must all be waiting inside it (so a
    // future placement releases them), not lost in limbo.
    for (&gang, members) in gangs {
        let active = assignments.iter().any(|&(g, _)| g == gang);
        if !active {
            for &m in members {
                let st = sys.tasks.state(m);
                assert!(
                    !st.is_ready() && !st.is_running(),
                    "gang {gang} owns no CPUs but member {m} is {st:?}"
                );
            }
        }
    }
}

fn random_mold_run(rng: &mut Rng) {
    let topo = {
        let z = machines();
        z[rng.range(0, z.len())].clone()
    };
    let n_cpus = topo.n_cpus();
    let sys = Arc::new(System::new(Arc::new(topo)));
    let s = MoldableGangScheduler::new(MoldableConfig {
        resize_hysteresis: 1 + rng.range(0, 4) as u32,
        ..Default::default()
    });
    let m = Marcel::with_system(&sys);

    // 2-4 gangs of 1-4 threads each.
    let mut gangs: BTreeMap<TaskId, Vec<TaskId>> = BTreeMap::new();
    let n_gangs = rng.range(2, 5);
    for gi in 0..n_gangs {
        let b = m.bubble_init();
        let mut members = Vec::new();
        for ti in 0..rng.range(1, 5) {
            let t = m.create_dontsched(format!("g{gi}t{ti}"));
            m.bubble_inserttask(b, t);
            members.push(t);
        }
        gangs.insert(b, members);
        s.wake(&sys, b);
    }
    let gang_ids: Vec<TaskId> = gangs.keys().copied().collect();
    let all_members: Vec<TaskId> = gangs.values().flatten().copied().collect();

    let mut running: Vec<Option<TaskId>> = vec![None; n_cpus];
    let mut remaining: std::collections::HashSet<TaskId> = all_members.iter().copied().collect();
    let mut blocked: Vec<TaskId> = Vec::new();
    let mut fuel = 400 * all_members.len() * n_cpus + 800;
    while !remaining.is_empty() && fuel > 0 {
        fuel -= 1;
        match rng.below(10) {
            // Random resize pressure, any gang, any time.
            0 => {
                let g = gang_ids[rng.range(0, gang_ids.len())];
                s.force_shrink(&sys, g);
            }
            1 => {
                let g = gang_ids[rng.range(0, gang_ids.len())];
                s.force_expand(&sys, g);
            }
            // Wake a blocked member.
            2 if !blocked.is_empty() => {
                let t = blocked.swap_remove(rng.range(0, blocked.len()));
                s.wake(&sys, t);
            }
            // Scheduling traffic.
            _ => {
                let cpu = rng.range(0, n_cpus);
                match running[cpu] {
                    Some(t) => {
                        let why = match rng.below(10) {
                            0..=2 => StopReason::Yield,
                            3 => StopReason::Block,
                            _ => StopReason::Terminate,
                        };
                        s.stop(&sys, CpuId(cpu), t, why);
                        match why {
                            StopReason::Terminate => {
                                remaining.remove(&t);
                            }
                            StopReason::Block => blocked.push(t),
                            _ => {}
                        }
                        running[cpu] = None;
                    }
                    None => {
                        if let Some(t) = s.pick(&sys, CpuId(cpu)) {
                            assert!(
                                !running.iter().flatten().any(|&r| r == t),
                                "double dispatch of {t}"
                            );
                            running[cpu] = Some(t);
                        }
                    }
                }
            }
        }
        member_census(&sys, &gangs);
        placement_invariants(&sys, &s, &gangs);
        // Drain the blocked pool when it is the only work left.
        if remaining.iter().all(|t| blocked.contains(t)) && running.iter().all(|r| r.is_none())
        {
            while let Some(t) = blocked.pop() {
                s.wake(&sys, t);
            }
        }
    }
    // Wind down: terminate what runs, re-wake what blocks, drain.
    for (cpu, slot) in running.iter().enumerate() {
        if let Some(t) = slot {
            s.stop(&sys, CpuId(cpu), *t, StopReason::Terminate);
            remaining.remove(t);
        }
    }
    while let Some(t) = blocked.pop() {
        s.wake(&sys, t);
    }
    let mut extra = 400 * all_members.len() * n_cpus + 800;
    while !remaining.is_empty() && extra > 0 {
        extra -= 1;
        let cpu = rng.range(0, n_cpus);
        if let Some(t) = s.pick(&sys, CpuId(cpu)) {
            s.stop(&sys, CpuId(cpu), t, StopReason::Terminate);
            remaining.remove(&t);
        }
    }
    assert!(
        remaining.is_empty(),
        "moldable lost {} of {} members on {}",
        remaining.len(),
        all_members.len(),
        sys.topo.name()
    );
    assert_eq!(sys.rq.total_queued(), 0, "runqueues not drained");
    for &t in &all_members {
        assert_eq!(sys.tasks.state(t), TaskState::Terminated, "{t} not terminated");
    }
}

#[test]
fn random_shrink_expand_never_loses_members() {
    check(0x301dab1e, 30, random_mold_run);
}

#[test]
fn moldable_beats_strict_gang_on_small_gangs() {
    // The policy's reason to exist (and the paper's §3.1 criticism of
    // Ousterhout fragmentation, measured): two 2-thread gangs on a
    // 4-CPU NUMA box run serially under strict gang scheduling but
    // side-by-side once the first gang's set shrinks to one node.
    use bubbles::config::SchedKind;
    use bubbles::sched::factory::make_default;
    use bubbles::sim::{Program, SimConfig};

    let run = |kind: SchedKind| -> u64 {
        let topo = Topology::numa(2, 2);
        let mut e = bubbles::apps::engine_with(&topo, make_default(kind), SimConfig::default());
        let sys = e.sys.clone();
        let m = Marcel::with_system(&sys);
        for gi in 0..2 {
            let b = m.bubble_init();
            for ti in 0..2 {
                let t = m.create_dontsched(format!("g{gi}t{ti}"));
                m.bubble_inserttask(b, t);
                e.set_program(t, Program::new().compute(2_000_000, 0.0, None));
            }
            e.wake(b);
        }
        e.run().expect("gang comparison run").total_time
    };
    let strict = run(SchedKind::Gang);
    let moldable = run(SchedKind::MoldableGang);
    assert!(
        (moldable as f64) < 0.75 * strict as f64,
        "moldable {moldable} must clearly beat strict gang {strict}"
    );
}
