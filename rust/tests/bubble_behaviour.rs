//! Behavioural suite of the bubble scheduler (moved out of
//! `src/sched/bubble.rs` when its mechanics were extracted into
//! `sched::core`): Figure-1 gang priorities, Figure-3 evolution,
//! Figure-4 late insertion, §3.3.3 regeneration, §4 accounting.

use std::sync::Arc;

use bubbles::marcel::Marcel;
use bubbles::sched::{BubbleConfig, BubbleScheduler, Scheduler, StopReason, System};
use bubbles::task::{BubblePhase, BurstLevel, TaskId, TaskState, PRIO_BUBBLE, PRIO_THREAD};
use bubbles::topology::{CpuId, LevelKind, Topology};
use bubbles::trace::Event;

fn system(topo: Topology) -> Arc<System> {
    Arc::new(System::new(Arc::new(topo)))
}

fn spawn_threads(sys: &System, sched: &dyn Scheduler, n: usize) -> Vec<TaskId> {
    (0..n)
        .map(|i| {
            let t = sys.tasks.new_thread(format!("w{i}"), PRIO_THREAD);
            sched.wake(sys, t);
            t
        })
        .collect()
}

fn drain_cpu(sys: &System, sched: &dyn Scheduler, cpu: CpuId) -> Vec<TaskId> {
    let mut order = Vec::new();
    while let Some(t) = sched.pick(sys, cpu) {
        assert_eq!(sys.tasks.state(t), TaskState::Running { cpu });
        order.push(t);
        sched.stop(sys, cpu, t, StopReason::Terminate);
    }
    order
}

fn sched() -> BubbleScheduler {
    BubbleScheduler::new(BubbleConfig::default())
}

#[test]
fn plain_threads_round_trip() {
    let sys = system(Topology::smp(2));
    let s = sched();
    let ts = spawn_threads(&sys, &s, 3);
    let order = drain_cpu(&sys, &s, CpuId(0));
    assert_eq!(order, ts);
    assert!(s.pick(&sys, CpuId(0)).is_none());
}

#[test]
fn yield_requeues_to_same_list() {
    let sys = system(Topology::smp(2));
    let s = sched();
    let ts = spawn_threads(&sys, &s, 1);
    let t = s.pick(&sys, CpuId(0)).unwrap();
    assert_eq!(t, ts[0]);
    s.stop(&sys, CpuId(0), t, StopReason::Yield);
    assert!(sys.tasks.state(t).is_ready());
    let t2 = s.pick(&sys, CpuId(0)).unwrap();
    assert_eq!(t2, t);
}

#[test]
fn bubble_descends_and_bursts_at_numa_level() {
    let sys = system(Topology::numa(2, 2));
    let s = sched();
    let m = Marcel::with_system(&sys);
    let b = m.bubble_init();
    let t1 = m.create_dontsched("a");
    let t2 = m.create_dontsched("b");
    m.bubble_inserttask(b, t1);
    m.bubble_inserttask(b, t2);
    sys.trace.set_enabled(true);
    s.wake(&sys, b);
    // cpu0 picks: bubble descends from root to numa0, bursts there,
    // then cpu0 gets a thread.
    let got = s.pick(&sys, CpuId(0)).unwrap();
    assert!(got == t1 || got == t2);
    // The burst must have happened on the NUMA-node list (depth 1).
    let records = sys.trace.records();
    let burst_list = records
        .iter()
        .find_map(|r| match r.event {
            Event::Burst { list, .. } => Some(list),
            _ => None,
        })
        .expect("no burst traced");
    assert_eq!(sys.topo.node(burst_list).depth, 1);
    assert_eq!(sys.topo.node(burst_list).kind, LevelKind::NumaNode);
    // The second thread is visible to cpu1 (same node).
    let got2 = s.pick(&sys, CpuId(1)).unwrap();
    assert!(got2 == t1 || got2 == t2);
    assert_ne!(got, got2);
}

#[test]
fn burst_level_leaf_rides_to_cpu_list() {
    let sys = system(Topology::numa(2, 2));
    let s = BubbleScheduler::new(BubbleConfig {
        default_burst: BurstLevel::Leaf,
        ..BubbleConfig::default()
    });
    let m = Marcel::with_system(&sys);
    let b = m.bubble_init();
    let t1 = m.create_dontsched("a");
    m.bubble_inserttask(b, t1);
    sys.trace.set_enabled(true);
    s.wake(&sys, b);
    let got = s.pick(&sys, CpuId(3)).unwrap();
    assert_eq!(got, t1);
    let burst_list = sys
        .trace
        .records()
        .iter()
        .find_map(|r| match r.event {
            Event::Burst { list, .. } => Some(list),
            _ => None,
        })
        .unwrap();
    assert_eq!(burst_list, sys.topo.leaf_of(CpuId(3)));
}

#[test]
fn higher_priority_task_wins_over_fifo_order() {
    let sys = system(Topology::numa(2, 2));
    let s = sched();
    let lo = sys.tasks.new_thread("lo", PRIO_THREAD);
    let hi = sys.tasks.new_thread("hi", bubbles::task::PRIO_HIGH);
    s.wake(&sys, lo);
    s.wake(&sys, hi);
    let got = s.pick(&sys, CpuId(0)).unwrap();
    assert_eq!(got, hi, "high priority wins despite FIFO order");
}

#[test]
fn local_list_wins_priority_ties() {
    let sys = system(Topology::numa(2, 2));
    let s = sched();
    let global = sys.tasks.new_thread("global", PRIO_THREAD);
    let local = sys.tasks.new_thread("local", PRIO_THREAD);
    s.wake(&sys, global); // root list
    // Place `local` directly on cpu0's leaf list.
    sys.tasks.with(local, |t| t.last_list = Some(sys.topo.leaf_of(CpuId(0))));
    s.wake(&sys, local);
    let got = s.pick(&sys, CpuId(0)).unwrap();
    assert_eq!(got, local, "ties must prefer the most local list");
}

#[test]
fn empty_bubble_terminates_on_burst() {
    let sys = system(Topology::smp(2));
    let s = sched();
    let m = Marcel::with_system(&sys);
    let b = m.bubble_init();
    s.wake(&sys, b);
    assert!(s.pick(&sys, CpuId(0)).is_none());
    assert_eq!(sys.tasks.state(b), TaskState::Terminated);
}

#[test]
fn thread_terminations_terminate_bubble() {
    let sys = system(Topology::smp(2));
    let s = sched();
    let m = Marcel::with_system(&sys);
    let b = m.bubble_init();
    let t1 = m.create_dontsched("a");
    let t2 = m.create_dontsched("b");
    m.bubble_inserttask(b, t1);
    m.bubble_inserttask(b, t2);
    s.wake(&sys, b);
    let a = s.pick(&sys, CpuId(0)).unwrap();
    let c = s.pick(&sys, CpuId(1)).unwrap();
    s.stop(&sys, CpuId(0), a, StopReason::Terminate);
    assert_ne!(sys.tasks.state(b), TaskState::Terminated);
    s.stop(&sys, CpuId(1), c, StopReason::Terminate);
    assert_eq!(sys.tasks.state(b), TaskState::Terminated);
}

#[test]
fn figure4_insert_after_wake() {
    // Figure 4 inserts thread2 *after* wake_up_bubble: the late
    // insertion must land on the burst bubble's home list.
    let sys = system(Topology::smp(2));
    let s = sched();
    let m = Marcel::with_system(&sys);
    let b = m.bubble_init();
    let t1 = m.create_dontsched("t1");
    m.bubble_inserttask(b, t1);
    s.wake(&sys, b);
    let got1 = s.pick(&sys, CpuId(0)).unwrap();
    assert_eq!(got1, t1);
    // Late insertion.
    let t2 = m.create_dontsched("t2");
    m.bubble_inserttask(b, t2);
    s.wake(&sys, t2);
    let got2 = s.pick(&sys, CpuId(1)).unwrap();
    assert_eq!(got2, t2);
    // Both must terminate the bubble.
    s.stop(&sys, CpuId(0), t1, StopReason::Terminate);
    s.stop(&sys, CpuId(1), t2, StopReason::Terminate);
    assert_eq!(sys.tasks.state(b), TaskState::Terminated);
}

#[test]
fn gang_scheduling_via_priorities() {
    // Figure 1: two pair-bubbles under a root bubble; threads
    // prioritised over bubbles. With 2 CPUs, the first burst pair
    // must fully occupy the machine before the second bubble bursts.
    let sys = system(Topology::smp(2));
    let s = BubbleScheduler::new(BubbleConfig {
        default_burst: BurstLevel::Immediate,
        ..BubbleConfig::default()
    });
    let m = Marcel::with_system(&sys);
    let root = m.bubble_init();
    let b1 = m.bubble_init();
    let b2 = m.bubble_init();
    let p1a = m.create_dontsched("p1a");
    let p1b = m.create_dontsched("p1b");
    let p2a = m.create_dontsched("p2a");
    let p2b = m.create_dontsched("p2b");
    m.bubble_inserttask(b1, p1a);
    m.bubble_inserttask(b1, p1b);
    m.bubble_inserttask(b2, p2a);
    m.bubble_inserttask(b2, p2b);
    m.bubble_insertbubble(root, b1);
    m.bubble_insertbubble(root, b2);
    s.wake(&sys, root);
    let x = s.pick(&sys, CpuId(0)).unwrap();
    let y = s.pick(&sys, CpuId(1)).unwrap();
    let first: std::collections::BTreeSet<TaskId> = [x, y].into();
    // Must both come from the same pair-bubble (gang!).
    assert!(
        first == [p1a, p1b].into() || first == [p2a, p2b].into(),
        "first gang mixed: {first:?}"
    );
}

#[test]
fn timeslice_regen_rotates_gangs() {
    let sys = system(Topology::smp(2));
    let s = BubbleScheduler::new(BubbleConfig {
        default_burst: BurstLevel::Immediate,
        default_timeslice: Some(100),
        ..BubbleConfig::default()
    });
    let m = Marcel::with_system(&sys);
    let root = m.bubble_init();
    let mk_pair = |tag: &str| {
        let b = m.bubble_init();
        let x = m.create_dontsched(format!("{tag}a"));
        let y = m.create_dontsched(format!("{tag}b"));
        m.bubble_inserttask(b, x);
        m.bubble_inserttask(b, y);
        (b, x, y)
    };
    let (b1, _p1a, _p1b) = mk_pair("p1");
    let (b2, _p2a, _p2b) = mk_pair("p2");
    m.bubble_insertbubble(root, b1);
    m.bubble_insertbubble(root, b2);
    s.wake(&sys, root);
    let x = s.pick(&sys, CpuId(0)).unwrap();
    let y = s.pick(&sys, CpuId(1)).unwrap();
    let gang1: std::collections::BTreeSet<TaskId> = [x, y].into();
    // Burn the gang's timeslice.
    let preempt_x = s.tick(&sys, CpuId(0), x, 60);
    let preempt_y = s.tick(&sys, CpuId(1), y, 60);
    assert!(preempt_x || preempt_y, "timeslice must trigger");
    s.stop(&sys, CpuId(0), x, StopReason::Preempt);
    s.stop(&sys, CpuId(1), y, StopReason::Preempt);
    // Next picks must be the *other* gang.
    let x2 = s.pick(&sys, CpuId(0)).unwrap();
    let y2 = s.pick(&sys, CpuId(1)).unwrap();
    let gang2: std::collections::BTreeSet<TaskId> = [x2, y2].into();
    assert!(gang2.is_disjoint(&gang1), "gangs must rotate: {gang1:?} vs {gang2:?}");
}

#[test]
fn idle_regen_rebalances_across_nodes() {
    let sys = system(Topology::numa(2, 1)); // 2 nodes, 1 cpu each
    let s = BubbleScheduler::new(BubbleConfig {
        regen_hysteresis: 0,
        thread_steal: false,
        ..BubbleConfig::default()
    });
    let m = Marcel::with_system(&sys);
    let b = m.bubble_init();
    let ts: Vec<TaskId> = (0..4).map(|i| m.create_dontsched(format!("w{i}"))).collect();
    for &t in &ts {
        m.bubble_inserttask(b, t);
    }
    s.wake(&sys, b);
    // cpu0 pulls the bubble to node 0 and bursts it there.
    let t0 = s.pick(&sys, CpuId(0)).unwrap();
    // cpu1 (other node) sees nothing; its pick triggers a
    // corrective regeneration, which per §4 must wait for the
    // running thread before the bubble can move up.
    assert!(s.pick(&sys, CpuId(1)).is_none());
    assert!(sys.metrics.regenerations.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    // The running thread finishes — "the last thread closes the
    // bubble and moves it up".
    s.stop(&sys, CpuId(0), t0, StopReason::Terminate);
    // Now cpu1 can pull the bubble down on its side and re-burst.
    let t1 = s.pick(&sys, CpuId(1)).expect("rebalanced work");
    assert_ne!(t0, t1);
    assert_eq!(sys.tasks.state(t1), TaskState::Running { cpu: CpuId(1) });
}

#[test]
fn thread_steal_fallback() {
    let sys = system(Topology::numa(2, 1));
    let s = BubbleScheduler::new(BubbleConfig {
        idle_regen: false,
        thread_steal: true,
        ..BubbleConfig::default()
    });
    // A loose thread stuck on cpu0's leaf list.
    let t = sys.tasks.new_thread("lone", PRIO_THREAD);
    sys.tasks.with(t, |x| x.last_list = Some(sys.topo.leaf_of(CpuId(0))));
    s.wake(&sys, t);
    // cpu1 can't see that list; stealing must save it.
    let got = s.pick(&sys, CpuId(1)).unwrap();
    assert_eq!(got, t);
    assert_eq!(sys.metrics.steals.load(std::sync::atomic::Ordering::Relaxed), 1);
}

#[test]
fn blocked_thread_wakes_back_to_home_list() {
    let sys = system(Topology::numa(2, 2));
    let s = sched();
    let m = Marcel::with_system(&sys);
    let b = m.bubble_init();
    let t1 = m.create_dontsched("a");
    let t2 = m.create_dontsched("b");
    m.bubble_inserttask(b, t1);
    m.bubble_inserttask(b, t2);
    s.wake(&sys, b);
    let x = s.pick(&sys, CpuId(0)).unwrap();
    s.stop(&sys, CpuId(0), x, StopReason::Block);
    assert_eq!(sys.tasks.state(x), TaskState::Blocked);
    s.wake(&sys, x);
    assert!(sys.tasks.state(x).is_ready());
    // It must be back on the bubble's home list (numa node 0).
    let list = sys.tasks.state(x).ready_list().unwrap();
    assert_eq!(sys.topo.node(list).kind, LevelKind::NumaNode);
}

#[test]
fn wake_into_closed_bubble_is_not_dropped() {
    // Regression: a member blocks, its bubble regenerates and *closes*,
    // then the member wakes. The wake must return it to the held
    // population (InBubble) so the next burst releases it — leaving it
    // Blocked would lose the thread forever.
    let sys = system(Topology::smp(2));
    let s = BubbleScheduler::new(BubbleConfig {
        default_burst: BurstLevel::Immediate,
        default_timeslice: Some(100),
        ..BubbleConfig::default()
    });
    let m = Marcel::with_system(&sys);
    let b = m.bubble_init();
    let t1 = m.create_dontsched("t1");
    let t2 = m.create_dontsched("t2");
    m.bubble_inserttask(b, t1);
    m.bubble_inserttask(b, t2);
    s.wake(&sys, b);
    let x = s.pick(&sys, CpuId(0)).unwrap();
    let y = s.pick(&sys, CpuId(1)).unwrap();
    // One member blocks…
    s.stop(&sys, CpuId(0), x, StopReason::Block);
    // …the bubble's timeslice expires: preventive regeneration closes
    // it once the remaining runner returns.
    assert!(s.tick(&sys, CpuId(1), y, 150));
    s.stop(&sys, CpuId(1), y, StopReason::Preempt);
    assert_eq!(sys.tasks.with(b, |t| t.bubble_data().phase), BubblePhase::Closed);
    // Now the blocked member wakes into the closed bubble.
    s.wake(&sys, x);
    assert_eq!(sys.tasks.state(x), TaskState::InBubble, "wake must not be dropped");
    // The next bursts must release *both* members; drain everything.
    let mut seen = std::collections::BTreeSet::new();
    for round in 0..20 {
        let cpu = CpuId(round % 2);
        if let Some(t) = s.pick(&sys, cpu) {
            seen.insert(t);
            s.stop(&sys, cpu, t, StopReason::Terminate);
        }
    }
    assert_eq!(seen, [t1, t2].into(), "both members must run to completion");
    assert_eq!(sys.tasks.state(b), TaskState::Terminated);
}

#[test]
fn no_task_lost_under_chaotic_schedule() {
    // Property: every created thread is eventually picked and
    // terminated; nothing vanishes.
    use bubbles::util::proptest::check;
    check(0xb0b, 25, |rng| {
        let topo = match rng.below(3) {
            0 => Topology::smp(4),
            1 => Topology::numa(2, 2),
            _ => Topology::deep(),
        };
        let n_cpus = topo.n_cpus();
        let sys = system(topo);
        let s = BubbleScheduler::new(BubbleConfig {
            regen_hysteresis: 0,
            ..Default::default()
        });
        let m = Marcel::with_system(&sys);
        let mut all_threads = Vec::new();
        for bi in 0..rng.range(1, 4) {
            let b = m.bubble_init();
            for ti in 0..rng.range(1, 5) {
                let t = m.create_dontsched(format!("b{bi}t{ti}"));
                m.bubble_inserttask(b, t);
                all_threads.push(t);
            }
            s.wake(&sys, b);
        }
        for i in 0..rng.range(0, 3) {
            let t = sys.tasks.new_thread(format!("loose{i}"), PRIO_THREAD);
            s.wake(&sys, t);
            all_threads.push(t);
        }
        let mut remaining: std::collections::HashSet<TaskId> =
            all_threads.iter().copied().collect();
        let mut fuel = 10_000;
        while !remaining.is_empty() && fuel > 0 {
            fuel -= 1;
            let cpu = CpuId(rng.range(0, n_cpus));
            if let Some(t) = s.pick(&sys, cpu) {
                if rng.chance(0.3) {
                    s.stop(&sys, cpu, t, StopReason::Yield);
                } else {
                    s.stop(&sys, cpu, t, StopReason::Terminate);
                    remaining.remove(&t);
                }
            }
        }
        assert!(remaining.is_empty(), "lost tasks: {remaining:?}");
    });
}

#[test]
fn bubble_priority_below_thread_keeps_machine_busy() {
    // Paper Figure 1 rationale: a bubble bursts only when running
    // threads can no longer occupy all processors.
    let sys = system(Topology::smp(2));
    let s = BubbleScheduler::new(BubbleConfig {
        default_burst: BurstLevel::Immediate,
        ..Default::default()
    });
    let m = Marcel::with_system(&sys);
    let a = sys.tasks.new_thread("a", PRIO_THREAD);
    let bt = sys.tasks.new_thread("b", PRIO_THREAD);
    s.wake(&sys, a);
    s.wake(&sys, bt);
    let bub = m.bubble_init();
    let c = m.create_dontsched("c");
    let d = m.create_dontsched("d");
    m.bubble_inserttask(bub, c);
    m.bubble_inserttask(bub, d);
    s.wake(&sys, bub);
    let x = s.pick(&sys, CpuId(0)).unwrap();
    let y = s.pick(&sys, CpuId(1)).unwrap();
    assert_eq!(
        std::collections::BTreeSet::from([x, y]),
        std::collections::BTreeSet::from([a, bt]),
        "threads must be scheduled before the bubble bursts"
    );
    assert_eq!(sys.tasks.with(bub, |t| t.bubble_data().phase), BubblePhase::Closed);
    assert_eq!(sys.tasks.prio(bub), PRIO_BUBBLE);
}
