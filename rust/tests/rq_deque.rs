//! Integration coverage for the lock-free two-tier runqueue
//! ([`bubbles::rq`]): the Chase-Lev fast lane layered in front of the
//! priority buckets, exercised through the same public `RqHierarchy`
//! surface the schedulers use.
//!
//! * exactly-once delivery under concurrent owners and thieves — no
//!   task lost, none served twice;
//! * the owner-order contract: the lane drains oldest-first, so FIFO
//!   is preserved across the lane/bucket boundary;
//! * bucket-preferred-on-tie, so lane traffic cannot starve entries
//!   that took the locked path;
//! * lane overflow spills to the buckets without loss;
//! * steals walk the topology's scan order — same-node siblings come
//!   before remote NUMA nodes, and a scan-order walk takes the closest
//!   queued task first.

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;

use bubbles::rq::{owner, RqHierarchy, FAST_LANE_CAP, FAST_LANE_PRIO};
use bubbles::task::TaskId;
use bubbles::topology::{CpuId, Topology};

#[test]
fn concurrent_owners_and_thieves_deliver_every_task_exactly_once() {
    let topo = Arc::new(Topology::numa(2, 2)); // 4 CPUs, 2 NUMA nodes
    let n_cpus = topo.n_cpus();
    let rq = Arc::new(RqHierarchy::new(&topo));
    let per_owner = 2_000usize;
    let owners_done = Arc::new(AtomicUsize::new(0));

    let mut owners = Vec::new();
    for w in 0..n_cpus {
        let rq = rq.clone();
        let topo = topo.clone();
        let owners_done = owners_done.clone();
        owners.push(thread::spawn(move || {
            owner::set_current_cpu(Some(CpuId(w)));
            let leaf = topo.leaf_of(CpuId(w));
            let mut got = Vec::new();
            for i in 0..per_owner {
                rq.push(leaf, TaskId(w * per_owner + i), FAST_LANE_PRIO);
                // Interleave owner-side picks so the lane's pop path
                // races the thieves' steal path on the same deque.
                if i % 3 == 0 {
                    if let Some((t, _)) = rq.pop_max(leaf) {
                        got.push(t);
                    }
                }
            }
            owners_done.fetch_add(1, Ordering::SeqCst);
            got
        }));
    }

    let mut thieves = Vec::new();
    for _ in 0..2 {
        let rq = rq.clone();
        let topo = topo.clone();
        let owners_done = owners_done.clone();
        thieves.push(thread::spawn(move || {
            let mut got = Vec::new();
            loop {
                // Load the flag *before* sweeping: once it reads full,
                // no new pushes can appear, so an empty sweep after
                // that point means the queues are truly drained.
                let all_done = owners_done.load(Ordering::SeqCst) == n_cpus;
                let mut empty_sweep = true;
                for c in 0..n_cpus {
                    if let Some((t, _)) = rq.pop_max(topo.leaf_of(CpuId(c))) {
                        got.push(t);
                        empty_sweep = false;
                    }
                }
                if all_done && empty_sweep {
                    return got;
                }
                std::hint::spin_loop();
            }
        }));
    }

    let mut seen = Vec::new();
    for h in owners {
        seen.extend(h.join().unwrap());
    }
    for h in thieves {
        seen.extend(h.join().unwrap());
    }
    // Defensive final drain from the main thread (no owner context, so
    // this also exercises the contextless pop path).
    for c in 0..n_cpus {
        while let Some((t, _)) = rq.pop_max(topo.leaf_of(CpuId(c))) {
            seen.push(t);
        }
    }

    assert_eq!(seen.len(), n_cpus * per_owner, "tasks lost or served twice");
    let uniq: HashSet<TaskId> = seen.iter().copied().collect();
    assert_eq!(uniq.len(), seen.len(), "duplicate delivery");
    assert_eq!(rq.total_queued(), 0, "counters out of sync with contents");
    let (lane_pushes, lane_pops) = rq.fast_lane_ops();
    assert!(lane_pushes > 0, "owner pushes never engaged the fast lane");
    assert!(lane_pops <= lane_pushes, "lane pops {lane_pops} > pushes {lane_pushes}");
}

#[test]
fn owner_pushes_drain_in_fifo_order_through_the_lane() {
    let topo = Topology::smp(4);
    let rq = RqHierarchy::new(&topo);
    let leaf = topo.leaf_of(CpuId(1));
    owner::set_current_cpu(Some(CpuId(1)));
    for i in 0..64 {
        rq.push(leaf, TaskId(i), FAST_LANE_PRIO);
    }
    let (lane_pushes, _) = rq.fast_lane_ops();
    assert_eq!(lane_pushes, 64, "owner pushes at thread prio must take the lane");
    for i in 0..64 {
        let (t, p) = rq.pop_max(leaf).expect("still queued");
        assert_eq!(t, TaskId(i), "lane must preserve arrival order");
        assert_eq!(p, FAST_LANE_PRIO);
    }
    assert!(rq.pop_max(leaf).is_none());
    owner::set_current_cpu(None);
}

#[test]
fn bucket_entries_win_ties_so_lane_traffic_cannot_starve_them() {
    let topo = Topology::smp(2);
    let rq = RqHierarchy::new(&topo);
    let leaf = topo.leaf_of(CpuId(0));
    // Lane push (owner context set) then a bucket push at the same
    // priority (no context — e.g. a remote waker).
    owner::set_current_cpu(Some(CpuId(0)));
    rq.push(leaf, TaskId(1), FAST_LANE_PRIO);
    owner::set_current_cpu(None);
    rq.push(leaf, TaskId(2), FAST_LANE_PRIO);
    // The bucket entry is served first on the tie: a stream of
    // owner-side lane pushes may never starve the locked path.
    assert_eq!(rq.pop_max(leaf), Some((TaskId(2), FAST_LANE_PRIO)));
    assert_eq!(rq.pop_max(leaf), Some((TaskId(1), FAST_LANE_PRIO)));
    assert!(rq.pop_max(leaf).is_none());
}

#[test]
fn lane_overflow_spills_to_the_buckets_without_loss() {
    let topo = Topology::smp(2);
    let rq = RqHierarchy::new(&topo);
    let leaf = topo.leaf_of(CpuId(0));
    owner::set_current_cpu(Some(CpuId(0)));
    let n = FAST_LANE_CAP + 16;
    for i in 0..n {
        rq.push(leaf, TaskId(i), FAST_LANE_PRIO);
    }
    assert_eq!(rq.len_of(leaf), n, "spilled pushes must still be counted");
    let mut seen = HashSet::new();
    while let Some((t, _)) = rq.pop_max(leaf) {
        assert!(seen.insert(t), "duplicate {t:?} across lane/bucket spill");
    }
    assert_eq!(seen.len(), n, "overflow lost tasks");
    assert_eq!(rq.total_queued(), 0);
    owner::set_current_cpu(None);
}

#[test]
fn steals_follow_the_hierarchy_scan_order() {
    let topo = Topology::numa(4, 4);
    let thief = CpuId(0);
    let own = topo.leaf_of(thief);
    let order: Vec<_> =
        topo.steal_order(thief).iter().copied().filter(|&l| l != own).collect();
    assert!(!order.is_empty());

    // The scan order itself is sorted by topological separation: a
    // same-node sibling never comes after a remote-node leaf.
    let sep = |l| topo.separation(thief, CpuId(topo.node(l).cpu_first));
    for pair in order.windows(2) {
        assert!(
            sep(pair[0]) <= sep(pair[1]),
            "steal order not distance-sorted: {:?} (sep {}) before {:?} (sep {})",
            pair[0],
            sep(pair[0]),
            pair[1],
            sep(pair[1])
        );
    }

    // Seed one task on the closest remote leaf and one on the farthest,
    // then walk the scan order the way `ops::steal_closest` does: the
    // close task must be taken first even though the far leaf was
    // populated first — and popping a non-owned leaf (the steal path
    // through the victim's fast lane) must succeed.
    let rq = RqHierarchy::new(&topo);
    let near = order[0];
    let far = *order.last().unwrap();
    assert!(sep(near) < sep(far), "numa(4,4) must separate near from far");
    // Populate through the victims' own lanes so the steal really
    // crosses the lock-free tier.
    owner::set_current_cpu(Some(CpuId(topo.node(far).cpu_first)));
    rq.push(far, TaskId(99), FAST_LANE_PRIO);
    owner::set_current_cpu(Some(CpuId(topo.node(near).cpu_first)));
    rq.push(near, TaskId(7), FAST_LANE_PRIO);
    owner::set_current_cpu(None);

    let mut stolen = Vec::new();
    for &l in &order {
        if let Some((t, _)) = rq.pop_max(l) {
            stolen.push(t);
        }
    }
    assert_eq!(
        stolen,
        vec![TaskId(7), TaskId(99)],
        "scan-order walk must take the closest queued task first"
    );
    assert_eq!(rq.total_queued(), 0);
}
