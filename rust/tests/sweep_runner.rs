//! End-to-end `repro sweep` tests: subprocess cell isolation, the
//! planted-failure / `--continue-on-failure` drill, content-addressed
//! determinism, and the regression gate — the sweep acceptance criteria.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

/// ≥2 policies × ≥2 machines × ≥2 seeds, all sim smoke cells.
const GRID: &str = "\
[grid]
experiment = \"memcmp\"
policy  = [\"afs\", \"memaware\"]
machine = [\"smp-4\", \"numa-4x4\"]
seed    = [1, 2]

[run]
engine = \"sim\"
smoke  = true
";

fn scratch(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("bubbles-sweep-e2e-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn repro(args: &[&str], envs: &[(&str, &str)], cwd: Option<&Path>) -> Output {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_repro"));
    cmd.args(args);
    for (k, v) in envs {
        cmd.env(k, v);
    }
    if let Some(d) = cwd {
        cmd.current_dir(d);
    }
    cmd.output().expect("spawn repro")
}

fn stdout_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).to_string()
}

/// The single content-addressed run directory under a sweep out dir.
fn only_subdir(dir: &Path) -> PathBuf {
    let mut subs: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.is_dir())
        .collect();
    assert_eq!(subs.len(), 1, "want exactly one run dir in {}: {subs:?}", dir.display());
    subs.pop().unwrap()
}

#[test]
fn planted_failure_completes_the_grid_and_exits_nonzero() {
    let root = scratch("plant");
    let grid_path = root.join("grid.toml");
    std::fs::write(
        &grid_path,
        format!("{GRID}\n[sweep]\nplant_fail = \"machine=smp-4 seed=2\"\n"),
    )
    .unwrap();
    let out_dir = root.join("results");
    let out = repro(
        &[
            "sweep",
            "--grid",
            &grid_path.to_string_lossy(),
            "-j",
            "4",
            "--continue-on-failure",
            "--out",
            &out_dir.to_string_lossy(),
        ],
        &[],
        None,
    );
    let stdout = stdout_of(&out);
    // Exit contract: any failed cell → 1; the other 6 cells still ran.
    assert_eq!(out.status.code(), Some(1), "{stdout}");
    assert!(stdout.contains("8 cells, 6 ok, 2 failed"), "{stdout}");
    assert!(!stdout.contains("skipped"), "continue-on-failure must run everything: {stdout}");
    let run = only_subdir(&out_dir);
    let manifest = std::fs::read_to_string(run.join("manifest.json")).unwrap();
    assert_eq!(manifest.matches("\"status\":\"ok\"").count(), 6, "{manifest}");
    assert_eq!(manifest.matches("\"status\":\"failed\"").count(), 2, "{manifest}");
    // Planted cells panic before writing, so exactly the ok cells left
    // artifacts behind.
    let artifacts = std::fs::read_dir(&run)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
        .filter(|n| n != "manifest.json")
        .count();
    assert_eq!(artifacts, 6, "one artifact per ok cell");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn identical_seeded_sweeps_are_byte_identical_and_gate_clean() {
    let root = scratch("det");
    let grid_path = root.join("grid.toml");
    std::fs::write(&grid_path, GRID).unwrap();
    let (a, b) = (root.join("a"), root.join("b"));
    for out_dir in [&a, &b] {
        let out = repro(
            &[
                "sweep",
                "--grid",
                &grid_path.to_string_lossy(),
                "-j",
                "2",
                "--out",
                &out_dir.to_string_lossy(),
            ],
            &[],
            None,
        );
        assert!(out.status.success(), "{}", stdout_of(&out));
    }
    let (ra, rb) = (only_subdir(&a), only_subdir(&b));
    assert_eq!(ra.file_name(), rb.file_name(), "same grid must hash to the same run dir");
    let mut names: Vec<String> = std::fs::read_dir(&ra)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
        .collect();
    names.sort();
    assert_eq!(names.len(), 9, "8 cell artifacts + manifest: {names:?}");
    for name in &names {
        assert_eq!(
            std::fs::read(ra.join(name)).unwrap(),
            std::fs::read(rb.join(name)).unwrap(),
            "`{name}` must be byte-identical across seeded runs"
        );
    }

    // Diffing the two runs gates clean with matched cells on both sides.
    let out = repro(&["sweep", "diff", &ra.to_string_lossy(), &rb.to_string_lossy()], &[], None);
    let stdout = stdout_of(&out);
    assert!(out.status.success(), "{stdout}");
    assert!(stdout.contains("gate: OK"), "{stdout}");
    assert!(stdout.contains("0 regressed"), "{stdout}");
    assert!(!stdout.contains("diff: 0 matched"), "diff must actually match cells: {stdout}");

    // The one-arg form reads the baseline from BENCH_BASELINE.
    let out = repro(
        &["sweep", "diff", &rb.to_string_lossy()],
        &[("BENCH_BASELINE", &ra.to_string_lossy())],
        None,
    );
    assert!(out.status.success(), "{}", stdout_of(&out));

    // The injected-regression drill: a 2x inflation must trip the gate
    // with the contract exit code.
    let out = repro(
        &["sweep", "diff", &ra.to_string_lossy(), &rb.to_string_lossy()],
        &[("SWEEP_INJECT_REGRESSION", "2.0")],
        None,
    );
    let stdout = stdout_of(&out);
    assert_eq!(out.status.code(), Some(2), "{stdout}");
    assert!(stdout.contains("REGRESSED"), "{stdout}");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn serve_rows_gate_through_sweep_diff() {
    // Two identically-seeded sim serve runs produce identical
    // BENCH_serve.json artifacts; `sweep diff` gates their
    // mix_makespan / p99_slowdown rows like any other cells.
    let root = scratch("serve");
    let (a, b) = (root.join("a"), root.join("b"));
    for dir in [&a, &b] {
        std::fs::create_dir_all(dir).unwrap();
        let out = repro(
            &["serve", "--engine", "sim", "--smoke", "--seed", "7"],
            &[],
            Some(dir),
        );
        assert!(out.status.success(), "{}", stdout_of(&out));
        assert!(dir.join("BENCH_serve.json").exists());
    }
    let (fa, fb) = (a.join("BENCH_serve.json"), b.join("BENCH_serve.json"));
    let out = repro(&["sweep", "diff", &fa.to_string_lossy(), &fb.to_string_lossy()], &[], None);
    let stdout = stdout_of(&out);
    assert!(out.status.success(), "{stdout}");
    assert!(stdout.contains("gate: OK"), "{stdout}");
    assert!(!stdout.contains("diff: 0 matched"), "serve rows must gate: {stdout}");
    let out = repro(
        &["sweep", "diff", &fa.to_string_lossy(), &fb.to_string_lossy()],
        &[("SWEEP_INJECT_REGRESSION", "2.0")],
        None,
    );
    let stdout = stdout_of(&out);
    assert_eq!(out.status.code(), Some(2), "{stdout}");
    assert!(stdout.contains("REGRESSED"), "{stdout}");
    let _ = std::fs::remove_dir_all(&root);
}
