//! Integration: config → scheduler → workload → simulator → report,
//! across every scheduler and machine preset.

use std::sync::Arc;

use bubbles::apps::conduction::{self, HeatParams};
use bubbles::apps::{engine_with, StructureMode};
use bubbles::config::{ExperimentConfig, SchedKind};
use bubbles::sched::baselines::make_default;
use bubbles::sim::SimConfig;
use bubbles::topology::Topology;

fn small() -> HeatParams {
    HeatParams { threads: 8, cycles: 4, work: 150_000, mem_fraction: 0.3 }
}

#[test]
fn every_scheduler_completes_conduction() {
    let topo = Topology::numa(2, 2);
    for kind in SchedKind::all() {
        if *kind == SchedKind::Gang {
            continue; // gang scheduling wants gang-structured work
        }
        let sched = make_default(*kind);
        let mut e = engine_with(&topo, sched, SimConfig::default());
        conduction::build(&mut e, StructureMode::Simple, &small());
        let rep = e.run().unwrap_or_else(|err| panic!("{kind:?}: {err}"));
        assert!(rep.total_time > 0, "{kind:?}");
    }
}

#[test]
fn every_machine_preset_runs_bubbles() {
    for preset in ["xeon-2x-ht", "numa-4x4", "deep", "smp-4", "numa-2x8"] {
        let topo = Topology::preset(preset).unwrap();
        let p = HeatParams { threads: topo.n_cpus(), ..small() };
        let rep = conduction::run(&topo, StructureMode::Bubbles, &p);
        assert!(rep.total_time > 0, "{preset}");
        assert!(rep.utilisation() > 0.1, "{preset}: {}", rep.utilisation());
    }
}

#[test]
fn config_file_end_to_end() {
    let toml = r#"
        [machine]
        levels = ["numa:2", "core:2"]
        numa_factor = 2.0
        [sched]
        kind = "bubble"
        burst = "numa"
        [workload]
        app = "conduction"
        threads = 4
        cycles = 3
        work = 100000
    "#;
    let cfg = ExperimentConfig::from_toml(toml).unwrap();
    let topo = cfg.machine.build_topology().unwrap();
    assert_eq!(topo.n_cpus(), 4);
    let sched = bubbles::sched::baselines::make(&cfg.sched);
    let mut e = engine_with(&topo, sched, SimConfig::default());
    conduction::build(
        &mut e,
        StructureMode::Bubbles,
        &HeatParams {
            threads: cfg.workload.threads,
            cycles: cfg.workload.cycles,
            work: cfg.workload.work,
            mem_fraction: cfg.workload.mem_fraction,
        },
    );
    assert!(e.run().unwrap().total_time > 0);
}

#[test]
fn simulation_is_deterministic_across_schedulers() {
    let topo = Topology::numa(2, 2);
    for kind in [SchedKind::Bubble, SchedKind::Ss, SchedKind::Afs] {
        let run_once = || {
            let sched = make_default(kind);
            let mut e = engine_with(&topo, sched, SimConfig::default());
            conduction::build(
                &mut e,
                if kind == SchedKind::Bubble { StructureMode::Bubbles } else { StructureMode::Simple },
                &small(),
            );
            e.run().unwrap().total_time
        };
        assert_eq!(run_once(), run_once(), "{kind:?} not deterministic");
    }
}

#[test]
fn jitter_seed_changes_timings_but_not_correctness() {
    let topo = Topology::numa(2, 2);
    let run_seed = |seed: u64| {
        let sched = make_default(SchedKind::Ss);
        let cfg = SimConfig { seed, ..SimConfig::default() };
        let mut e = engine_with(&topo, sched, cfg);
        conduction::build(&mut e, StructureMode::Simple, &small());
        e.run().unwrap().total_time
    };
    let a = run_seed(1);
    let b = run_seed(2);
    assert_ne!(a, b, "different seeds should perturb timings");
    let rel = (a as f64 - b as f64).abs() / a as f64;
    assert!(rel < 0.25, "seeds should not change the outcome scale: {rel}");
}

#[test]
fn metrics_are_coherent_after_a_run() {
    let topo = Topology::numa(2, 2);
    let sched = Arc::new(bubbles::sched::BubbleScheduler::new(Default::default()));
    let mut e = engine_with(&topo, sched, SimConfig::default());
    conduction::build(&mut e, StructureMode::Bubbles, &small());
    e.run().unwrap();
    let m = &e.sys.metrics;
    let picks = m.picks.load(std::sync::atomic::Ordering::Relaxed);
    // 8 threads × 4 cycles: at least one pick per thread per cycle.
    assert!(picks >= 32, "picks {picks}");
    assert!(m.bursts.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    assert!(m.utilisation() > 0.0);
}
