//! Striped-region properties (ISSUE-4 satellite): random stripe
//! declarations cover exactly the declared nodes with per-stripe
//! footprints summing to the region size, and random
//! touch/next-touch/migrate sequences conserve bytes — in the raw
//! registry, in the footprint hierarchy, and in the per-node pressure
//! view.

use std::sync::Arc;

use bubbles::marcel::Marcel;
use bubbles::mem::AllocPolicy;
use bubbles::sched::System;
use bubbles::topology::{CpuId, Topology};
use bubbles::util::proptest;

const N_NODES: usize = 4;

fn fresh() -> Arc<System> {
    Arc::new(System::new(Arc::new(Topology::numa(N_NODES, 4))))
}

#[test]
fn random_stripe_declarations_cover_exactly_the_declared_nodes() {
    proptest::check(0x57217e, 40, |rng| {
        let sys = fresh();
        for _ in 0..20 {
            let n_stripes = rng.range(1, N_NODES + 1);
            let mut nodes = Vec::with_capacity(n_stripes);
            for _ in 0..n_stripes {
                nodes.push(rng.below(N_NODES as u64) as usize);
            }
            let size = 1 + rng.below(1 << 22);
            let r = sys.mem.alloc_striped(size, &nodes);
            let info = sys.mem.info(r);
            // One stripe per declared node, in declaration order.
            let got: Vec<usize> = info.stripes.iter().map(|s| s.node).collect();
            assert_eq!(got, nodes, "stripes must cover exactly the declared nodes");
            // Per-stripe sizes sum to the region size, split near-evenly.
            let sizes: Vec<u64> = info.stripes.iter().map(|s| s.size).collect();
            assert_eq!(sizes.iter().sum::<u64>(), size);
            let min = *sizes.iter().min().unwrap();
            let max = *sizes.iter().max().unwrap();
            assert!(max - min <= 1, "stripes must split evenly: {sizes:?}");
            // The pressure view accounts the same bytes.
            let by_node = info.homed_bytes_per_node(N_NODES);
            assert_eq!(by_node.iter().sum::<u64>(), size);
        }
        // Total pressure equals total homed bytes (every region in
        // this test is homed at declaration).
        let mut total = 0u64;
        for info in sys.mem.regions.snapshot() {
            total += info.size;
        }
        assert_eq!(sys.mem.pressure_view().iter().sum::<u64>(), total);
    });
}

#[test]
fn random_touch_sequences_conserve_bytes_everywhere() {
    proptest::check(0x57217e2, 30, |rng| {
        let sys = fresh();
        let m = Marcel::with_system(&sys);
        // A bubble forest plus loose threads to attribute into.
        let mut tasks = Vec::new();
        for b in 0..2 {
            let bubble = m.bubble_init();
            for k in 0..3 {
                let t = m.create_dontsched(format!("b{b}t{k}"));
                m.bubble_inserttask(bubble, t);
                tasks.push(t);
            }
        }
        for k in 0..2 {
            tasks.push(m.create_dontsched(format!("loose{k}")));
        }
        let n_cpus = sys.topo.n_cpus();
        let mut regions = Vec::new();
        for step in 0..160 {
            match rng.below(6) {
                0 => {
                    let size = 1 + rng.below(1 << 20);
                    let n_stripes = rng.range(1, N_NODES + 1);
                    let mut nodes = Vec::with_capacity(n_stripes);
                    for _ in 0..n_stripes {
                        nodes.push(rng.below(N_NODES as u64) as usize);
                    }
                    regions.push(sys.mem.alloc_striped(size, &nodes));
                }
                1 => {
                    let policy = match rng.below(3) {
                        0 => AllocPolicy::FirstTouch,
                        1 => AllocPolicy::RoundRobin,
                        _ => AllocPolicy::Fixed(rng.below(N_NODES as u64) as usize),
                    };
                    regions.push(sys.mem.alloc(1 + rng.below(1 << 20), policy));
                }
                2 if !regions.is_empty() => {
                    let r = *rng.choose(&regions);
                    let t = *rng.choose(&tasks);
                    sys.mem.attach(&sys.tasks, t, r);
                }
                3 if !regions.is_empty() => {
                    let r = *rng.choose(&regions);
                    let cpu = CpuId(rng.below(n_cpus as u64) as usize);
                    // The engine-shared touch path keeps metrics in
                    // step with the registry's touch counter.
                    sys.touch_region(r, cpu);
                }
                4 if !regions.is_empty() => {
                    sys.mem.mark_next_touch(*rng.choose(&regions));
                }
                5 => {
                    sys.mem.mark_task_regions_next_touch(*rng.choose(&tasks));
                }
                _ => {}
            }
            // Bytes are conserved at every step: region sizes never
            // change, stripes only move between nodes.
            for &r in &regions {
                let info = sys.mem.info(r);
                if !info.stripes.is_empty() {
                    let sum: u64 = info.stripes.iter().map(|s| s.size).sum();
                    assert_eq!(sum, info.size, "stripe bytes leaked at step {step}");
                }
            }
            assert!(sys.mem.conserved(&sys.tasks), "conservation broken at step {step}");
            assert!(
                sys.mem.hierarchy_consistent(&sys.tasks),
                "footprint hierarchy broken at step {step}"
            );
            // Pressure view == homed bytes, every step.
            let mut homed = 0u64;
            for info in sys.mem.regions.snapshot() {
                if info.is_homed() {
                    homed += info.size;
                }
            }
            assert_eq!(
                sys.mem.pressure_view().iter().sum::<u64>(),
                homed,
                "pressure leaked at step {step}"
            );
        }
        // Touch accounting: every registry touch was exactly one local
        // or remote access.
        use std::sync::atomic::Ordering;
        let locals = sys.metrics.local_accesses.load(Ordering::Relaxed);
        let remotes = sys.metrics.remote_accesses.load(Ordering::Relaxed);
        assert_eq!(locals + remotes, sys.mem.regions.total_touches());
    });
}
