//! Factory-wide conformance suite: every policy the registry
//! enumerates — including any future entry, which is covered here
//! automatically — must be well-behaved on a fixed workload matrix
//! (flat SMP, the paper's numa(4,4), and the asymmetric machine):
//!
//! * **termination** — the simulated run completes (no deadlock, no
//!   lost wakeups), for loose threads and for bubble-structured work;
//! * **task conservation** — every spawned thread ends `Terminated`,
//!   and nothing but inert bubble tasks may remain on the runqueues;
//! * **no permanent starvation** — under fair round-robin polling,
//!   every woken task is eventually picked within a fuel budget;
//! * **stats consistency** — the incremental `LoadStats` running
//!   counters return to zero on every component, and the pick/steal
//!   metrics add up;
//! * **memory invariants, on both engines** — per-task/per-bubble
//!   footprint conservation after every run
//!   (`MemState::hierarchy_consistent`), and touch accounting:
//!   `local_ratio ∈ [0,1]` with locals + remotes equal to the
//!   registry's total touches. The native leg runs every registry
//!   entry over real green threads recording touches via `GreenApi`,
//!   so a future policy inherits the gate on *both* engines
//!   automatically.
//!
//! Workloads are deliberately free of *inter-gang* coupling (no global
//! barrier across independent gangs) so strict space/time-sharing
//! policies (`gang`) can pass them too; barrier-coupled behaviour is
//! exercised by the scheduler-specific suites.
//!
//! The **cross-job matrix** additionally serves a mixed multi-tenant
//! job stream (the `serve` admission layer: per-job bubble subtrees
//! woken by a replayed arrival schedule) under every registry policy on
//! smp(4) and the paper's numa(4,4): every job must finish (no runnable
//! job starved while the mix drains), every member must terminate, and
//! each job's footprint must stay conserved within its own subtree.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use bubbles::apps::engine_with;
use bubbles::marcel::Marcel;
use bubbles::sched::factory;
use bubbles::sched::{Scheduler, StopReason, System};
use bubbles::sim::{Program, SimConfig, SimEngine};
use bubbles::task::{TaskId, TaskState, PRIO_THREAD};
use bubbles::topology::{CpuId, LevelId, Topology};

fn machines() -> Vec<Topology> {
    vec![Topology::smp(4), Topology::numa(4, 4), Topology::asym()]
}

fn engine(topo: &Topology, sched: Arc<dyn Scheduler>) -> SimEngine {
    engine_with(topo, sched, SimConfig::default())
}

/// Post-run invariants shared by every workload.
fn assert_consistent(name: &str, machine: &str, sys: &System, threads: &[TaskId]) {
    for &t in threads {
        assert_eq!(
            sys.tasks.state(t),
            TaskState::Terminated,
            "{name} on {machine}: {t} not terminated"
        );
    }
    // LoadStats: every per-component running counter back to zero.
    for i in 0..sys.topo.n_components() {
        assert_eq!(
            sys.stats.running(LevelId(i)),
            0,
            "{name} on {machine}: running counter leaked on component {i}"
        );
    }
    // Only inert bubble tasks may remain queued.
    for (list, task, _prio) in sys.rq.snapshot() {
        assert!(
            sys.tasks.is_bubble(task),
            "{name} on {machine}: thread {task} leaked on list {list:?}"
        );
    }
    // Footprint conservation (regions were declared in every workload):
    // the aggregate invariant plus the strong per-task/per-bubble one.
    assert!(sys.mem.conserved(&sys.tasks), "{name} on {machine}: footprint leak");
    assert!(
        sys.mem.hierarchy_consistent(&sys.tasks),
        "{name} on {machine}: footprint hierarchy inconsistent"
    );
    // Touch accounting: every registry touch was counted as exactly one
    // local or remote access, and the ratio is a valid fraction.
    let locals = sys.metrics.local_accesses.load(Ordering::Relaxed);
    let remotes = sys.metrics.remote_accesses.load(Ordering::Relaxed);
    assert_eq!(
        locals + remotes,
        sys.mem.regions.total_touches(),
        "{name} on {machine}: touch accounting mismatch"
    );
    let lr = sys.metrics.local_ratio();
    assert!((0.0..=1.0).contains(&lr), "{name} on {machine}: local_ratio {lr}");
    // Metrics add up: every thread was dispatched at least once, and
    // steals never exceed picks.
    let picks = sys.metrics.picks.load(Ordering::Relaxed);
    let steals = sys.metrics.steals.load(Ordering::Relaxed);
    assert!(
        picks >= threads.len() as u64,
        "{name} on {machine}: {picks} picks for {} threads",
        threads.len()
    );
    assert!(steals <= picks, "{name} on {machine}: steals {steals} > picks {picks}");
}

/// Independent loose compute threads (no coupling at all): every
/// policy, including strict gang time-sharing, must drain this.
fn flat_workload(name: &str, topo: &Topology) {
    let sched = factory::lookup(name).map(|e| {
        factory::make(&bubbles::config::SchedConfig {
            kind: e.kind,
            ..Default::default()
        })
    });
    let sched = sched.expect("registered policy");
    let mut e = engine(topo, sched);
    let n = topo.n_cpus() + 2;
    let mut threads = Vec::with_capacity(n);
    for i in 0..n {
        let r = e.alloc_region_sized(1 << 20, bubbles::sim::AllocPolicy::FirstTouch);
        let prog = Program::new()
            .compute(120_000, 0.3, Some(r))
            .compute(120_000, 0.3, Some(r))
            .compute(120_000, 0.3, Some(r));
        let t = e.add_thread(format!("flat{i}"), PRIO_THREAD, prog);
        e.attach_region(t, r);
        e.wake(t);
        threads.push(t);
    }
    let rep = e
        .run()
        .unwrap_or_else(|err| panic!("{name} on {}: flat run failed: {err}", topo.name()));
    assert!(rep.total_time > 0);
    assert_consistent(name, topo.name(), &e.sys, &threads);
}

/// Bubble-structured work: one flat bubble per NUMA node (no nesting,
/// no inter-bubble coupling), woken separately — gangs for the gang
/// family, burstable groups for the bubble scheduler, flattened by the
/// opportunists.
fn bubbled_workload(name: &str, topo: &Topology) {
    let sched = factory::make(&bubbles::config::SchedConfig {
        kind: factory::lookup(name).expect("registered policy").kind,
        ..Default::default()
    });
    let mut e = engine(topo, sched);
    let sys = e.sys.clone();
    let m = Marcel::with_system(&sys);
    let groups = sys.topo.n_numa().max(2);
    let per = sys.topo.n_cpus().div_ceil(groups).max(1);
    let mut threads = Vec::new();
    let mut bubbles_list = Vec::new();
    for g in 0..groups {
        let b = m.bubble_init();
        for k in 0..per {
            let t = m.create_dontsched(format!("g{g}t{k}"));
            m.bubble_inserttask(b, t);
            let r = e.alloc_region_sized(1 << 20, bubbles::sim::AllocPolicy::FirstTouch);
            m.attach_region(t, r);
            e.set_program(
                t,
                Program::new().compute(100_000, 0.3, Some(r)).compute(100_000, 0.3, Some(r)),
            );
            threads.push(t);
        }
        bubbles_list.push(b);
    }
    for &b in &bubbles_list {
        e.wake(b);
    }
    let rep = e
        .run()
        .unwrap_or_else(|err| panic!("{name} on {}: bubbled run failed: {err}", topo.name()));
    assert!(rep.total_time > 0);
    assert_consistent(name, topo.name(), &e.sys, &threads);
}

/// Fair round-robin polling drains every woken task within a fuel
/// budget: no policy may starve a task forever.
fn starvation_freedom(name: &str, topo: &Topology) {
    let sys = Arc::new(System::new(Arc::new(topo.clone())));
    let sched = factory::make(&bubbles::config::SchedConfig {
        kind: factory::lookup(name).expect("registered policy").kind,
        ..Default::default()
    });
    let n_cpus = sys.topo.n_cpus();
    let n = 3 * n_cpus;
    let mut remaining = std::collections::HashSet::new();
    for i in 0..n {
        let t = sys.tasks.new_thread(format!("s{i}"), PRIO_THREAD);
        sched.wake(&sys, t);
        remaining.insert(t);
    }
    let mut fuel = 60 * n * n_cpus + 400;
    let mut cpu = 0;
    while !remaining.is_empty() && fuel > 0 {
        fuel -= 1;
        if let Some(t) = sched.pick(&sys, CpuId(cpu)) {
            assert!(
                remaining.contains(&t),
                "{name} on {}: {t} picked twice",
                sys.topo.name()
            );
            sched.stop(&sys, CpuId(cpu), t, StopReason::Terminate);
            remaining.remove(&t);
        }
        cpu = (cpu + 1) % n_cpus;
    }
    assert!(
        remaining.is_empty(),
        "{name} on {}: {} tasks starved under fair polling",
        sys.topo.name(),
        remaining.len()
    );
    assert_eq!(sys.rq.total_queued(), 0, "{name}: runqueues not drained");
    for i in 0..sys.topo.n_components() {
        assert_eq!(sys.stats.running(LevelId(i)), 0, "{name}: running counter leaked");
    }
}

/// Transparent scheduler wrapper counting `tick` deliveries. The
/// native executor must charge every segment to the policy through
/// `Scheduler::tick` (that is what makes gang rotation, moldable
/// rotation and bubble preventive regeneration live on real OS
/// workers), so the native leg asserts the count below.
struct TickProbe {
    inner: Arc<dyn Scheduler>,
    ticks: std::sync::atomic::AtomicU64,
}

impl Scheduler for TickProbe {
    fn name(&self) -> String {
        self.inner.name()
    }
    fn wake(&self, sys: &System, task: TaskId) {
        self.inner.wake(sys, task)
    }
    fn pick(&self, sys: &System, cpu: CpuId) -> Option<TaskId> {
        self.inner.pick(sys, cpu)
    }
    fn stop(&self, sys: &System, cpu: CpuId, task: TaskId, why: StopReason) {
        self.inner.stop(sys, cpu, task, why)
    }
    fn tick(&self, sys: &System, cpu: CpuId, task: TaskId, elapsed: u64) -> bool {
        self.ticks.fetch_add(1, Ordering::Relaxed);
        assert!(elapsed > 0, "segments must charge non-zero time");
        self.inner.tick(sys, cpu, task, elapsed)
    }
}

/// Native-engine memory leg: bubble-structured green threads (one
/// bubble per NUMA node, no inter-gang coupling) whose bodies record
/// region touches through `GreenApi`; afterwards the run must satisfy
/// the same invariants [`assert_consistent`] enforces on the sim legs
/// — touches attributed on real OS workers included, and every policy
/// must have seen `tick` for every executed segment.
fn native_mem_workload(name: &str, topo: &Topology) {
    use bubbles::exec::Executor;
    let sys = Arc::new(System::new(Arc::new(topo.clone())));
    let sched: Arc<TickProbe> = Arc::new(TickProbe {
        inner: factory::make(&bubbles::config::SchedConfig {
            kind: factory::lookup(name).expect("registered policy").kind,
            ..Default::default()
        }),
        ticks: std::sync::atomic::AtomicU64::new(0),
    });
    let m = Marcel::with_system(&sys);
    let mut ex = Executor::new(sys.clone(), sched.clone());
    let groups = sys.topo.n_numa().max(2);
    let per = sys.topo.n_cpus().div_ceil(groups).max(1);
    let touches_each = 3u64;
    let mut threads = Vec::new();
    let mut bubbles_list = Vec::new();
    for g in 0..groups {
        let b = m.bubble_init();
        for k in 0..per {
            let t = m.create_dontsched(format!("g{g}t{k}"));
            m.bubble_inserttask(b, t);
            let r = sys.mem.alloc(1 << 20, bubbles::mem::AllocPolicy::FirstTouch);
            sys.mem.attach(&sys.tasks, t, r);
            ex.register(t, move |api| {
                for _ in 0..touches_each {
                    api.touch_region(r);
                    api.yield_now();
                }
            });
            threads.push(t);
        }
        bubbles_list.push(b);
    }
    for &b in &bubbles_list {
        sched.wake(&sys, b);
    }
    ex.run();
    let machine = topo.name();
    assert_consistent(name, machine, &sys, &threads);
    // Touches were actually attributed on the native workers.
    let locals = sys.metrics.local_accesses.load(Ordering::Relaxed);
    let remotes = sys.metrics.remote_accesses.load(Ordering::Relaxed);
    assert_eq!(
        locals + remotes,
        threads.len() as u64 * touches_each,
        "{name} on {machine}: native touches lost"
    );
    // Tick delivery: every thread ran at least one segment, and the
    // executor must have charged each segment to the policy.
    let ticks = sched.ticks.load(Ordering::Relaxed);
    assert!(
        ticks >= threads.len() as u64,
        "{name} on {machine}: only {ticks} ticks for {} threads",
        threads.len()
    );
}

/// Strict gang scheduling on the native engine with more gangs than
/// CPUs: only timeslice rotation (tick → preempt → requeue) lets every
/// gang make progress before the active one finishes, and every gang
/// must still run to completion.
#[test]
fn native_strict_gang_rotates_across_gangs() {
    use bubbles::exec::Executor;
    let topo = Topology::smp(2);
    let sys = Arc::new(System::new(Arc::new(topo)));
    let sched = factory::make(&bubbles::config::SchedConfig {
        kind: bubbles::config::SchedKind::Gang,
        timeslice: Some(20_000), // 20µs of wall time per gang slice
        ..Default::default()
    });
    let m = Marcel::with_system(&sys);
    let mut ex = Executor::new(sys.clone(), sched.clone());
    let done = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let mut threads = Vec::new();
    for g in 0..4 {
        let b = m.bubble_init();
        for k in 0..2 {
            let t = m.create_dontsched(format!("g{g}k{k}"));
            m.bubble_inserttask(b, t);
            let d = done.clone();
            ex.register(t, move |api| {
                for i in 0..40u64 {
                    for _ in 0..5_000 {
                        std::hint::black_box(i);
                    }
                    api.yield_now();
                }
                d.fetch_add(1, Ordering::SeqCst);
            });
            threads.push(t);
        }
        sched.wake(&sys, b);
    }
    ex.run();
    assert_eq!(done.load(Ordering::SeqCst), 8, "every gang must finish");
    for t in threads {
        assert_eq!(sys.tasks.state(t), TaskState::Terminated);
    }
    assert!(
        sys.metrics.preemptions.load(Ordering::Relaxed) > 0,
        "tick-driven preemption must fire on the native engine"
    );
    assert!(
        sys.metrics.regenerations.load(Ordering::Relaxed) > 0,
        "gang rotation must fire before the active gang finishes"
    );
}

#[test]
fn every_registered_policy_holds_memory_invariants_on_the_native_engine() {
    for entry in factory::registry() {
        for topo in machines() {
            native_mem_workload(entry.name, &topo);
        }
    }
}

#[test]
fn every_registered_policy_completes_the_flat_matrix() {
    for entry in factory::registry() {
        for topo in machines() {
            flat_workload(entry.name, &topo);
        }
    }
}

#[test]
fn every_registered_policy_completes_the_bubbled_matrix() {
    for entry in factory::registry() {
        for topo in machines() {
            bubbled_workload(entry.name, &topo);
        }
    }
}

#[test]
fn no_registered_policy_starves_tasks() {
    for entry in factory::registry() {
        for topo in machines() {
            starvation_freedom(entry.name, &topo);
        }
    }
}

/// The hierarchy every leg above schedules on IS the two-tier lockless
/// runqueue: single-CPU leaves carry a Chase-Lev fast lane in front of
/// the priority buckets, and both engines (the native workers natively,
/// the simulator per event) run with the owner context pointing at the
/// executing CPU. Pin that structurally for every machine in the
/// matrix, then drive every registry policy through a fair-polling
/// termination/conservation run with the owner context set — wake,
/// pick, one yield-requeue per task, terminate — so owner-side
/// enqueues and picks exercise the lock-free path. Lane engagement is
/// asserted in aggregate across the registry: policies that enqueue on
/// the root only (e.g. `ss`) are entitled to zero lane traffic of
/// their own, but the affinity family requeues yields on
/// `leaf_of(cpu)` and must light the lanes up.
#[test]
fn every_registered_policy_conserves_on_the_lockless_runqueue() {
    use bubbles::rq::owner;
    for topo in machines() {
        let sys = System::new(Arc::new(topo.clone()));
        for c in 0..topo.n_cpus() {
            let leaf = topo.leaf_of(CpuId(c));
            assert_eq!(
                sys.rq.list(leaf).fast_lane_owner(),
                Some(CpuId(c)),
                "{}: leaf of cpu{c} carries no fast lane",
                topo.name()
            );
        }
    }
    let mut lane_pushes = 0u64;
    let mut lane_pops = 0u64;
    for entry in factory::registry() {
        let topo = Topology::numa(4, 4);
        let sys = Arc::new(System::new(Arc::new(topo)));
        let sched = factory::make_default(entry.kind);
        let n_cpus = sys.topo.n_cpus();
        let n = 3 * n_cpus;
        let mut remaining = std::collections::HashSet::new();
        for i in 0..n {
            let t = sys.tasks.new_thread(format!("lf{i}"), PRIO_THREAD);
            owner::set_current_cpu(Some(CpuId(i % n_cpus)));
            sched.wake(&sys, t);
            remaining.insert(t);
        }
        let mut requeued = std::collections::HashSet::new();
        let mut fuel = 120 * n * n_cpus + 800;
        let mut cpu = 0;
        while !remaining.is_empty() && fuel > 0 {
            fuel -= 1;
            owner::set_current_cpu(Some(CpuId(cpu)));
            if let Some(t) = sched.pick(&sys, CpuId(cpu)) {
                assert!(
                    remaining.contains(&t),
                    "{}: {t} picked after termination",
                    entry.name
                );
                // First pick yields (the affinity family requeues on
                // leaf_of(cpu) — with the context set, a lane push);
                // the second pick terminates.
                if requeued.insert(t) {
                    sched.stop(&sys, CpuId(cpu), t, StopReason::Yield);
                } else {
                    sched.stop(&sys, CpuId(cpu), t, StopReason::Terminate);
                    remaining.remove(&t);
                }
            }
            cpu = (cpu + 1) % n_cpus;
        }
        owner::set_current_cpu(None);
        assert!(
            remaining.is_empty(),
            "{}: {} tasks lost on the lockless runqueue",
            entry.name,
            remaining.len()
        );
        assert_eq!(
            sys.rq.total_queued(),
            0,
            "{}: lockless runqueues not drained",
            entry.name
        );
        for i in 0..sys.topo.n_components() {
            assert_eq!(
                sys.stats.running(LevelId(i)),
                0,
                "{}: running counter leaked on component {i}",
                entry.name
            );
        }
        let (pu, po) = sys.rq.fast_lane_ops();
        assert!(po <= pu, "{}: lane pops {po} exceed pushes {pu}", entry.name);
        lane_pushes += pu;
        lane_pops += po;
    }
    assert!(
        lane_pushes > 0 && lane_pops > 0,
        "no registry policy engaged the fast lanes (pushes {lane_pushes}, pops {lane_pops})"
    );
}

/// Cross-job conformance: a mixed multi-tenant stream (small/medium/
/// large shapes, all three deadline classes, flat and bubbled job
/// structures) served through the `serve` admission layer under the
/// given policy. The policy never sees the admission layer — the
/// [`bubbles::serve::JobTracker`] wrapper observes the scheduler
/// protocol — so every registry entry must drain the mix unmodified.
fn served_job_matrix(name: &str, topo: &Topology) {
    use bubbles::serve::{build_job, generate, GenConfig, JobBook, JobTracker, JOB_REGION_BYTES};
    use bubbles::task::PRIO_HIGH;
    let entry = factory::lookup(name).expect("registered policy");
    let book = JobBook::new();
    let tracker: Arc<dyn Scheduler> =
        Arc::new(JobTracker::new(factory::make_default(entry.kind), book.clone()));
    let mut e = engine(topo, tracker);
    let arrivals = generate(&GenConfig { jobs: 12, mean_gap: 5_000, ..GenConfig::default() });
    let mut driver = Program::new();
    let mut members = Vec::new();
    for (i, a) in arrivals.iter().enumerate() {
        let built = build_job(&e.sys, &a.spec, i);
        for (&t, &r) in built.members.iter().zip(built.regions.iter()) {
            let mut p = Program::new();
            for _ in 0..a.spec.cycles.max(1) {
                p = p.compute(a.spec.work.max(1), a.spec.mem_fraction, Some(r));
            }
            e.set_program(t, p);
        }
        book.register(&a.spec, &built);
        driver = driver.compute(a.gap.max(1), 0.0, None).wake(built.root);
        members.extend(built.members.iter().copied());
    }
    let d = e.add_thread("arrivals", PRIO_HIGH, driver);
    e.wake(d);
    e.run()
        .unwrap_or_else(|err| panic!("{name} on {}: serve run failed: {err}", topo.name()));
    let machine = topo.name();
    // Per-job lifecycle + conservation. A job left unfinished while the
    // engine drained would mean the policy starved a runnable job while
    // other jobs' CPUs went idle to completion — the run above would
    // have deadlocked or this stays stamped `None`.
    let recs = book.records();
    assert_eq!(recs.len(), arrivals.len(), "{name} on {machine}: jobs lost from the book");
    assert_eq!(
        book.admission_order().len(),
        arrivals.len(),
        "{name} on {machine}: admissions lost"
    );
    for r in &recs {
        assert!(r.arrived.is_some(), "{name} on {machine}: job {} never admitted", r.id);
        assert!(r.first_dispatch.is_some(), "{name} on {machine}: job {} starved", r.id);
        assert!(r.finished.is_some(), "{name} on {machine}: job {} never finished", r.id);
        for &t in &r.members {
            assert_eq!(
                e.sys.tasks.state(t),
                TaskState::Terminated,
                "{name} on {machine}: job {} member {t} not terminated",
                r.id
            );
        }
        // Per-job footprint conservation: every member region is touched
        // (mem-bound fraction > 0) hence homed, and its bytes must roll
        // up to exactly the job's own root — no bleed across subtrees.
        assert_eq!(
            e.sys.mem.footprint.total(r.root),
            r.regions.len() as u64 * JOB_REGION_BYTES,
            "{name} on {machine}: job {} footprint leaked out of its subtree",
            r.id
        );
    }
    // The driver thread terminated too, and the global invariants
    // (hierarchy-consistent footprints included) still hold.
    members.push(d);
    assert_consistent(name, machine, &e.sys, &members);
}

#[test]
fn every_registered_policy_serves_a_multi_tenant_job_stream() {
    for entry in factory::registry() {
        for topo in [Topology::smp(4), Topology::numa(4, 4)] {
            served_job_matrix(entry.name, &topo);
        }
    }
}

#[test]
fn registry_is_complete_and_buildable() {
    // The conformance matrix above runs whatever the registry lists;
    // this pins that the listing itself covers every SchedKind and
    // that names round-trip, so a future policy cannot dodge the suite
    // by registering half-way.
    use bubbles::config::SchedKind;
    assert_eq!(factory::registry().len(), SchedKind::all().len());
    for kind in SchedKind::all() {
        let e = factory::info(*kind);
        let s = factory::make_default(*kind);
        assert_eq!(s.name(), e.name, "{:?}", kind);
        assert_eq!(SchedKind::parse(e.name), Some(*kind));
    }
}
