//! Integration: the full three-layer stack — marcel bubbles + bubble
//! scheduler + native fibers + **PJRT-executed Pallas kernels** — on a
//! small striped conduction mesh, validated against the sequential
//! whole-mesh result.
//!
//! Skipped (with a notice) when `make artifacts` has not been run.

use std::sync::{Arc, Mutex};

use bubbles::exec::Executor;
use bubbles::marcel::Marcel;
use bubbles::runtime::service::PjrtService;
use bubbles::sched::{BubbleConfig, BubbleScheduler, System};
use bubbles::topology::Topology;

const ROWS: usize = 8; // artifact conduction_r4_c32 serves 2 stripes of 4
const COLS: usize = 32;
const STRIPES: usize = 2;
const STRIPE_H: usize = ROWS / STRIPES;
const ALPHA: f32 = 0.2;
const ITERS: usize = 12;

fn initial() -> Vec<f32> {
    (0..ROWS * COLS).map(|i| ((i * 37) % 100) as f32 / 10.0).collect()
}

fn stripe_with_halo(mesh: &[f32], s: usize) -> Vec<f32> {
    let mut out = Vec::new();
    let top = if s == 0 { 0 } else { s * STRIPE_H - 1 };
    out.extend_from_slice(&mesh[top * COLS..(top + 1) * COLS]);
    out.extend_from_slice(&mesh[s * STRIPE_H * COLS..(s + 1) * STRIPE_H * COLS]);
    let bot = if s == STRIPES - 1 { ROWS - 1 } else { (s + 1) * STRIPE_H };
    out.extend_from_slice(&mesh[bot * COLS..(bot + 1) * COLS]);
    out
}

/// Pure-rust oracle of one whole-mesh step (same scheme as ref.py).
fn step_reference(mesh: &[f32]) -> Vec<f32> {
    let idx = |r: usize, c: usize| r * COLS + c;
    let mut out = vec![0.0; ROWS * COLS];
    for r in 0..ROWS {
        for c in 0..COLS {
            if c == 0 || c == COLS - 1 {
                out[idx(r, c)] = mesh[idx(r, c)];
                continue;
            }
            let up = mesh[idx(r.saturating_sub(1), c)];
            let down = mesh[idx((r + 1).min(ROWS - 1), c)];
            let center = mesh[idx(r, c)];
            out[idx(r, c)] =
                center + ALPHA * (up + down + mesh[idx(r, c - 1)] + mesh[idx(r, c + 1)] - 4.0 * center);
        }
    }
    out
}

#[test]
fn striped_pjrt_run_matches_rust_oracle() {
    let Ok(svc) = PjrtService::start_default() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    // Full stack on a 2-node machine.
    let sys = Arc::new(System::new(Arc::new(Topology::numa(2, 1))));
    let sched = Arc::new(BubbleScheduler::new(BubbleConfig::default()));
    let m = Marcel::over(sys.clone(), sched.clone());
    let mut ex = Executor::new(sys, sched);
    let bufs: Arc<[Mutex<Vec<f32>>; 2]> =
        Arc::new([Mutex::new(initial()), Mutex::new(initial())]);
    let bar = ex.alloc_barrier(STRIPES);

    let bubble = m.bubble_init();
    for s in 0..STRIPES {
        let t = m.create_dontsched(format!("stripe{s}"));
        m.bubble_inserttask(bubble, t);
        let h = svc.handle();
        let bufs = bufs.clone();
        ex.register(t, move |api| {
            for it in 0..ITERS {
                let input = {
                    let cur = bufs[it % 2].lock().unwrap();
                    stripe_with_halo(&cur, s)
                };
                let out = h
                    .exec(
                        "conduction_r4_c32",
                        vec![(input, vec![STRIPE_H + 2, COLS]), (vec![ALPHA], vec![1])],
                    )
                    .expect("stencil");
                {
                    let mut next = bufs[(it + 1) % 2].lock().unwrap();
                    next[s * STRIPE_H * COLS..(s + 1) * STRIPE_H * COLS]
                        .copy_from_slice(&out);
                }
                api.barrier(bar);
            }
        });
    }
    m.wake_up_bubble(bubble);
    ex.run();

    // Oracle: ITERS whole-mesh steps in pure rust.
    let mut want = initial();
    for _ in 0..ITERS {
        want = step_reference(&want);
    }
    let got = bufs[ITERS % 2].lock().unwrap().clone();
    let max_diff = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "striped PJRT run diverged: {max_diff}");
}

#[test]
fn residual_kernel_agrees_with_rust() {
    let Ok(svc) = PjrtService::start_default() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let h = svc.handle();
    let a: Vec<f32> = (0..4 * 32).map(|i| i as f32).collect();
    let mut b = a.clone();
    b[77] += 4.25;
    let out = h
        .exec("residual_r4_c32", vec![(a, vec![4, 32]), (b, vec![4, 32])])
        .unwrap();
    assert!((out[0] - 4.25).abs() < 1e-6);
}
