//! Property tests over the scheduling-primitives core
//! (`bubbles::sched::core`): scan-order correctness on asymmetric and
//! deep machines, and task conservation (no lost or duplicated TaskId
//! across wake/pick/stop/steal) for the bubble scheduler *and* every
//! baseline, driven through the shared `Scheduler` trait.

use std::sync::Arc;

use bubbles::config::SchedKind;
use bubbles::sched::factory;
use bubbles::sched::{Scheduler, StopReason, System};
use bubbles::task::{TaskId, TaskState, PRIO_THREAD};
use bubbles::topology::{CpuId, LevelId, Topology};
use bubbles::util::proptest::check;
use bubbles::util::Rng;

fn zoo() -> Vec<Topology> {
    vec![
        Topology::smp(1),
        Topology::smp(5),
        Topology::numa(2, 2),
        Topology::numa(3, 2),
        Topology::xeon_2x_ht(),
        Topology::deep(),
        Topology::asym(),
    ]
}

// ------------------------------------------------------ scan orders

#[test]
fn scan_orders_cover_exactly_the_machine() {
    for topo in zoo() {
        for c in 0..topo.n_cpus() {
            let cpu = CpuId(c);
            let chain = topo.covering(cpu);
            let loc = topo.locality_order(cpu);

            // The covering chain is exactly the most-local prefix…
            assert_eq!(&loc[..chain.len()], chain, "{}: cpu{c} prefix", topo.name());
            // …and the covering/non-covering split is exact.
            for (i, &l) in loc.iter().enumerate() {
                assert_eq!(
                    topo.node(l).covers(cpu),
                    i < chain.len(),
                    "{}: cpu{c} position {i}",
                    topo.name()
                );
            }
            // Every component appears exactly once.
            let mut ids: Vec<usize> = loc.iter().map(|l| l.0).collect();
            ids.sort_unstable();
            assert_eq!(ids, (0..topo.n_components()).collect::<Vec<_>>());

            // Beyond the prefix, hierarchical distance never decreases.
            let leaf_depth = topo.node(topo.leaf_of(cpu)).depth;
            let dist = |l: LevelId| leaf_depth - topo.node(topo.hoist_towards(l, cpu)).depth;
            for w in loc[chain.len()..].windows(2) {
                assert!(
                    dist(w[0]) <= dist(w[1]),
                    "{}: cpu{c} locality not distance-sorted",
                    topo.name()
                );
            }

            // Descent is the reversed covering chain.
            let mut rev: Vec<LevelId> = chain.to_vec();
            rev.reverse();
            assert_eq!(topo.descent_order(cpu), &rev[..]);

            // Steal order: every other CPU's leaf exactly once,
            // separation non-decreasing (closest victims first).
            let steal = topo.steal_order(cpu);
            assert_eq!(steal.len(), topo.n_cpus() - 1);
            let mut leaves: Vec<usize> = steal.iter().map(|l| l.0).collect();
            leaves.sort_unstable();
            let mut expect: Vec<usize> = (0..topo.n_cpus())
                .filter(|&o| o != c)
                .map(|o| topo.leaf_of(CpuId(o)).0)
                .collect();
            expect.sort_unstable();
            assert_eq!(leaves, expect);
            let sep = |l: &LevelId| topo.separation(cpu, CpuId(topo.node(*l).cpu_first));
            for w in steal.windows(2) {
                assert!(sep(&w[0]) <= sep(&w[1]), "{}: steal order", topo.name());
            }

            // Hoist targets always cover the CPU and are ancestors.
            for i in 0..topo.n_components() {
                let l = LevelId(i);
                let h = topo.hoist_towards(l, cpu);
                assert!(topo.node(h).covers(cpu), "{}: hoist covers", topo.name());
                let mut cur = Some(l);
                let mut ok = false;
                while let Some(x) = cur {
                    if x == h {
                        ok = true;
                        break;
                    }
                    cur = topo.node(x).parent;
                }
                assert!(ok, "{}: hoist target not an ancestor-or-self", topo.name());
            }
        }
    }
}

// ------------------------------------------------- task conservation

fn conservation_for(kind: SchedKind, rng: &mut Rng) {
    let topo = {
        let z = zoo();
        z[rng.range(0, z.len())].clone()
    };
    let n_cpus = topo.n_cpus();
    let sys = Arc::new(System::new(Arc::new(topo)));
    let sched = factory::make_default(kind);

    // A forest of bubbles plus loose threads. Opportunist baselines
    // flatten the bubbles; the bubble scheduler evolves them; gang
    // treats them as gangs — conservation must hold regardless.
    let m = bubbles::marcel::Marcel::with_system(&sys);
    let mut threads = Vec::new();
    for bi in 0..rng.range(0, 3) {
        let b = m.bubble_init();
        for ti in 0..rng.range(1, 4) {
            let t = m.create_dontsched(format!("b{bi}t{ti}"));
            m.bubble_inserttask(b, t);
            threads.push(t);
        }
        sched.wake(&sys, b);
    }
    for i in 0..rng.range(1, 8) {
        let t = sys.tasks.new_thread(format!("loose{i}"), PRIO_THREAD);
        sched.wake(&sys, t);
        threads.push(t);
    }

    // Gang wedges on blocked *loose* threads unless a tick rotates the
    // machine; the chaotic harness runs tickless, so it only blocks
    // under schedulers with per-CPU progress.
    let may_block = kind != SchedKind::Gang;

    let mut remaining: std::collections::HashSet<TaskId> = threads.iter().copied().collect();
    let mut running: Vec<Option<TaskId>> = vec![None; n_cpus];
    let mut blocked: Vec<TaskId> = Vec::new();
    let mut fuel = 300 * threads.len().max(1) * n_cpus + 500;
    while !remaining.is_empty() && fuel > 0 {
        fuel -= 1;
        // Occasionally wake a blocked thread.
        if !blocked.is_empty() && rng.chance(0.3) {
            let t = blocked.swap_remove(rng.range(0, blocked.len()));
            sched.wake(&sys, t);
            continue;
        }
        let cpu = rng.range(0, n_cpus);
        match running[cpu] {
            Some(t) => {
                let why = match rng.below(10) {
                    0..=3 => StopReason::Yield,
                    4 if may_block => StopReason::Block,
                    _ => StopReason::Terminate,
                };
                sched.stop(&sys, CpuId(cpu), t, why);
                match why {
                    StopReason::Terminate => {
                        remaining.remove(&t);
                    }
                    StopReason::Block => blocked.push(t),
                    _ => {}
                }
                running[cpu] = None;
            }
            None => {
                if let Some(t) = sched.pick(&sys, CpuId(cpu)) {
                    // No duplication: nobody else may hold t.
                    assert!(
                        !running.iter().flatten().any(|&r| r == t),
                        "{kind:?}: double dispatch of {t}"
                    );
                    assert_eq!(
                        sys.tasks.state(t),
                        TaskState::Running { cpu: CpuId(cpu) },
                        "{kind:?}: dispatched task not Running"
                    );
                    running[cpu] = Some(t);
                }
            }
        }
        // Drain the blocked pool when it is the only work left.
        if remaining.iter().all(|t| blocked.contains(t))
            && running.iter().all(|r| r.is_none())
        {
            while let Some(t) = blocked.pop() {
                sched.wake(&sys, t);
            }
        }
    }
    // Terminate whatever is still on a CPU, then drain to empty.
    for (cpu, slot) in running.iter().enumerate() {
        if let Some(t) = slot {
            sched.stop(&sys, CpuId(cpu), *t, StopReason::Terminate);
            remaining.remove(t);
        }
    }
    while let Some(t) = blocked.pop() {
        sched.wake(&sys, t);
    }
    let mut extra = 300 * threads.len().max(1) * n_cpus + 500;
    while !remaining.is_empty() && extra > 0 {
        extra -= 1;
        let cpu = rng.range(0, n_cpus);
        if let Some(t) = sched.pick(&sys, CpuId(cpu)) {
            sched.stop(&sys, CpuId(cpu), t, StopReason::Terminate);
            remaining.remove(&t);
        }
    }
    assert!(
        remaining.is_empty(),
        "{kind:?} lost {} of {} tasks on {}",
        remaining.len(),
        threads.len(),
        sys.topo.name()
    );
    // Nothing leaks onto the runqueues: all threads terminated.
    assert_eq!(sys.rq.total_queued(), 0, "{kind:?}: runqueues not drained");
    for &t in &threads {
        assert_eq!(sys.tasks.state(t), TaskState::Terminated, "{kind:?}: {t} not terminated");
    }
}

#[test]
fn conservation_bubble() {
    check(0xc0de01, 25, |rng| conservation_for(SchedKind::Bubble, rng));
}

#[test]
fn conservation_ss() {
    check(0xc0de02, 20, |rng| conservation_for(SchedKind::Ss, rng));
}

#[test]
fn conservation_gss() {
    check(0xc0de03, 20, |rng| conservation_for(SchedKind::Gss, rng));
}

#[test]
fn conservation_tss() {
    check(0xc0de04, 20, |rng| conservation_for(SchedKind::Tss, rng));
}

#[test]
fn conservation_afs() {
    check(0xc0de05, 20, |rng| conservation_for(SchedKind::Afs, rng));
}

#[test]
fn conservation_lds() {
    check(0xc0de06, 20, |rng| conservation_for(SchedKind::Lds, rng));
}

#[test]
fn conservation_cafs() {
    check(0xc0de07, 20, |rng| conservation_for(SchedKind::Cafs, rng));
}

#[test]
fn conservation_hafs() {
    check(0xc0de08, 20, |rng| conservation_for(SchedKind::Hafs, rng));
}

#[test]
fn conservation_bound() {
    check(0xc0de09, 20, |rng| conservation_for(SchedKind::Bound, rng));
}

#[test]
fn conservation_gang() {
    check(0xc0de0a, 20, |rng| conservation_for(SchedKind::Gang, rng));
}

#[test]
fn conservation_memaware() {
    check(0xc0de0b, 20, |rng| conservation_for(SchedKind::Memaware, rng));
}

// ----------------------------------------------- running-count stats

/// The incremental running counters agree with ground truth under a
/// chaotic schedule.
#[test]
fn load_stats_running_counts_stay_consistent() {
    check(0x57a75, 25, |rng| {
        let topo = {
            let z = zoo();
            z[rng.range(0, z.len())].clone()
        };
        let n_cpus = topo.n_cpus();
        let sys = Arc::new(System::new(Arc::new(topo)));
        let sched = factory::make_default(SchedKind::Afs);
        for i in 0..rng.range(1, 12) {
            let t = sys.tasks.new_thread(format!("t{i}"), PRIO_THREAD);
            sched.wake(&sys, t);
        }
        let mut running: Vec<Option<TaskId>> = vec![None; n_cpus];
        for _ in 0..400 {
            let cpu = rng.range(0, n_cpus);
            match running[cpu] {
                Some(t) => {
                    let why =
                        if rng.chance(0.5) { StopReason::Yield } else { StopReason::Terminate };
                    sched.stop(&sys, CpuId(cpu), t, why);
                    running[cpu] = None;
                }
                None => running[cpu] = sched.pick(&sys, CpuId(cpu)),
            }
            // Ground truth at every step, for every component.
            let truth = running.iter().flatten().count();
            assert_eq!(sys.stats.running(sys.topo.root()), truth);
            for c in 0..n_cpus {
                let leaf = sys.topo.leaf_of(CpuId(c));
                let expect = usize::from(running[c].is_some());
                assert_eq!(sys.stats.running(leaf), expect, "leaf of cpu{c}");
            }
        }
    });
}
