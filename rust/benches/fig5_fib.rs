//! Figure 5 — fibonacci gain (%) vs thread count on both paper
//! machines. Set BENCH_FULL=1 for the full 2..512 sweep.

use bubbles::apps::fib::FibParams;
use bubbles::experiments::fig5;
use bubbles::topology::Topology;

fn main() {
    let full = std::env::var("BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let counts: Vec<usize> =
        if full { fig5::default_thread_counts() } else { vec![4, 16, 64, 128] };
    println!("Figure 5 — bubble gain over the classical scheduler");
    println!("(paper: (a) 30-40% from 16 threads; (b) 40% @32 → 80% @512)\n");
    for topo in [Topology::xeon_2x_ht(), Topology::numa(4, 4)] {
        let series = fig5::run(&topo, &counts, &FibParams::default());
        println!("{}", series.render());
    }
}
