//! Ablation (§3.3.3 / §3.4): regeneration & stealing policies on the
//! terminal-imbalance workload (where rebalancing should help) and the
//! barrier-coupled AMR workload (where the paper's ping-pong caveat
//! bites).

use bubbles::apps::amr::{AmrParams, SkewParams};
use bubbles::experiments::ablations;
use bubbles::topology::Topology;

fn main() {
    let topo = Topology::numa(4, 4);
    println!("{}", ablations::regeneration_skewed(&topo, &SkewParams::default()).render());
    let p = AmrParams { cycles: 12, redraw_every: 3, ..Default::default() };
    println!("{}", ablations::regeneration(&topo, &p).render());
}
