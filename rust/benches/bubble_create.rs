//! §5.1 prose: "Creation and destruction of a bubble holding a thread
//! does not cost much more than creation and destruction of a simple
//! thread: the cost increases from 3.3 µs to 3.7 µs" (≈ 1.12×).

use bubbles::bench::{black_box, Bench};
use bubbles::marcel::Marcel;
use bubbles::topology::Topology;

fn main() {
    let mut b = Bench::new("bubble_create");

    let thread_only = {
        let m = Marcel::new(Topology::numa(4, 4));
        b.bench("thread create", || {
            let t = m.create_dontsched("t");
            black_box(t);
        })
        .summary
        .median
    };
    let thread_in_bubble = {
        let m = Marcel::new(Topology::numa(4, 4));
        b.bench("bubble+thread create+insert", || {
            let bb = m.bubble_init();
            let t = m.create_dontsched("t");
            m.bubble_inserttask(bb, t);
            black_box((bb, t));
        })
        .summary
        .median
    };
    b.report();
    println!(
        "\nratio bubble/thread = {:.2}x (paper: 3.7/3.3 = 1.12x)",
        thread_in_bubble / thread_only
    );
}
