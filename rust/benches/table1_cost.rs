//! Table 1 — scheduler micro-costs: Yield (list search) and Switch
//! (synchronisation + context switch), for the flat per-CPU structure,
//! the full bubble hierarchy, and kernel threads.
//!
//! Paper (2.66 GHz P4 Xeon): marcel 186/84 ns, bubbles 250/148 ns,
//! NPTL 672/1488 ns. The shape to check: hierarchy costs a small
//! constant factor over flat; both are far below kernel threads.

use bubbles::experiments::table1;

fn main() {
    let user_switch = table1::fiber_switch_ns();
    let os_switch = table1::os_switch_ns();
    let t = table1::run(user_switch, os_switch);
    println!("Table 1 — measured on this testbed");
    println!("(paper: marcel 186/84, bubbles 250/148, NPTL 672/1488 ns)\n");
    println!("{}", t.render());

    let flat = &t.rows[0];
    let deep = &t.rows[1];
    let os = &t.rows[2];
    println!(
        "ratios: hierarchy/flat yield = {:.2}x (paper 1.34x), os/user switch = {:.1}x (paper ~10x)",
        deep.yield_ns / flat.yield_ns,
        os.switch_ns / deep.switch_ns,
    );
}
