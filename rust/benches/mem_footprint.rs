//! Memory-subsystem microbench: the registry/footprint hot paths a
//! memory-aware policy leans on.
//!
//! * `touch_homed` — the per-compute-chunk registry touch (stable home,
//!   no migration): the hottest mem/ path in the simulator.
//! * `touch_next_touch_pingpong` — worst-case next-touch migration:
//!   every touch re-homes the region across nodes.
//! * `attach_depth4` — footprint attribution up a 4-deep bubble chain.
//! * `dominant_node` — the policy-side placement query.
//!
//! Results print as a table *and* land in `BENCH_mem.json` (same shape
//! as `BENCH_rq.json`), so CI accumulates the perf trajectory. Honors
//! `BENCH_FAST=1` for smoke runs.

use std::sync::Arc;

use bubbles::bench::{black_box, Bench};
use bubbles::marcel::Marcel;
use bubbles::mem::AllocPolicy;
use bubbles::sched::System;
use bubbles::topology::{CpuId, Topology};

fn main() {
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let sys = Arc::new(System::new(Arc::new(Topology::numa(4, 4))));
    let m = Marcel::with_system(&sys);

    // A 4-deep bubble chain: root > mid > leafb > thread.
    let root = m.bubble_init();
    let mid = m.bubble_init();
    let leafb = m.bubble_init();
    let t = m.create_dontsched("worker");
    let t2 = m.create_dontsched("worker2");
    m.bubble_insertbubble(root, mid);
    m.bubble_insertbubble(mid, leafb);
    m.bubble_inserttask(leafb, t);
    m.bubble_inserttask(leafb, t2);

    let homed = m.region_alloc(1 << 20, AllocPolicy::Fixed(0));
    m.attach_region(t, homed);
    let pingpong = m.region_alloc(1 << 20, AllocPolicy::Fixed(0));
    m.attach_region(t, pingpong);

    let mut b = Bench::new("mem_footprint");

    b.bench("touch_homed", || {
        // cpu0 is on node 0 == the region's home: stable-state touch.
        black_box(sys.mem.touch(&sys.tasks, &sys.topo, homed, CpuId(0)));
    });

    let mut flip = false;
    b.bench("touch_next_touch_pingpong", || {
        // Alternate nodes with the mark always set: every touch
        // migrates and re-attributes the footprint up the chain.
        sys.mem.mark_next_touch(pingpong);
        let cpu = if flip { CpuId(0) } else { CpuId(15) };
        flip = !flip;
        black_box(sys.mem.touch(&sys.tasks, &sys.topo, pingpong, cpu));
    });

    let mut who = false;
    b.bench("attach_depth4", || {
        // Bounce ownership between two deep threads: one sub + one add
        // walk of the 4-deep bubble chain per call.
        let owner = if who { t } else { t2 };
        who = !who;
        sys.mem.attach(&sys.tasks, owner, homed);
    });

    b.bench("dominant_node", || {
        black_box(sys.mem.dominant_node(root));
    });

    b.report();

    let rows: Vec<String> = b
        .results()
        .iter()
        .map(|r| {
            format!(
                "{{\"name\":\"{}\",\"mean_ns\":{:.1},\"median_ns\":{:.1},\"p95_ns\":{:.1}}}",
                r.name, r.summary.mean, r.summary.median, r.summary.p95
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"mem_footprint\",\n  \"mode\": \"{}\",\n  \"machine\": \"{}\",\n  \"results\": [{}]\n}}\n",
        if fast { "fast" } else { "full" },
        sys.topo.name(),
        rows.join(",")
    );
    match std::fs::write("BENCH_mem.json", &json) {
        Ok(()) => println!("\nwrote BENCH_mem.json"),
        Err(e) => eprintln!("\ncould not write BENCH_mem.json: {e}"),
    }
}
