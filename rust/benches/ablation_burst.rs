//! Ablation (§3.3.1): bursting-level sweep — affinity (deep burst) vs
//! processor utilisation (high burst) on the conduction workload.

use bubbles::apps::conduction::HeatParams;
use bubbles::experiments::ablations;
use bubbles::topology::Topology;

fn main() {
    let full = std::env::var("BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let p = HeatParams {
        cycles: if full { 60 } else { 15 },
        ..HeatParams::conduction()
    };
    for topo in [Topology::numa(4, 4), Topology::deep()] {
        println!("machine: {}", topo.name());
        println!("{}", ablations::burst_level(&topo, &p).render());
    }
}
