//! Ablation (§3.1 / Figure 1): gang scheduling comparison.
//!
//! Ousterhout gangs leave processors idle ("a single machine can only
//! run one gang at a time, even if it is small"); the bubble scheduler
//! generalises gangs via priorities (Figure 1), letting spare
//! processors burst the next bubble. We run G gangs of K threads on a
//! P-CPU machine with K < P and compare makespans.

use std::sync::Arc;

use bubbles::apps::engine_with;
use bubbles::marcel::Marcel;
use bubbles::sched::baselines::GangScheduler;
use bubbles::sched::{BubbleConfig, BubbleScheduler, Scheduler};
use bubbles::sim::{Program, SimConfig};
use bubbles::task::BurstLevel;
use bubbles::topology::Topology;
use bubbles::util::fmt::Table;

fn run(gang_style: bool, gangs: usize, per_gang: usize, work: u64) -> u64 {
    let topo = Topology::smp(8);
    let sched: Arc<dyn Scheduler> = if gang_style {
        Arc::new(GangScheduler::new(1_000_000))
    } else {
        Arc::new(BubbleScheduler::new(BubbleConfig {
            default_burst: BurstLevel::Immediate,
            default_timeslice: Some(1_000_000),
            ..BubbleConfig::default()
        }))
    };
    let mut e = engine_with(&topo, sched, SimConfig::default());
    let sys = e.sys.clone();
    let m = Marcel::with_system(&sys);
    let root = m.bubble_init();
    for g in 0..gangs {
        let b = m.bubble_init();
        for k in 0..per_gang {
            let t = m.create_dontsched(format!("g{g}t{k}"));
            m.bubble_inserttask(b, t);
            e.set_program(t, Program::new().compute(work, 0.2, None));
        }
        m.bubble_insertbubble(root, b);
    }
    if gang_style {
        // Ousterhout: each gang is queued independently.
        let contents = sys.tasks.with(root, |t| t.kind_contents_snapshot());
        for b in contents {
            e.wake(b);
        }
    } else {
        e.wake(root);
    }
    e.run().expect("run").total_time
}

fn main() {
    println!("gang scheduling vs bubble gangs (8 CPUs, gangs of 4 threads)\n");
    let mut t = Table::new(&["gangs", "ousterhout gang (Mcycles)", "bubble gangs (Mcycles)", "bubble speedup"]);
    for gangs in [2usize, 4, 8] {
        let gang = run(true, gangs, 4, 4_000_000);
        let bubble = run(false, gangs, 4, 4_000_000);
        t.row(&[
            gangs.to_string(),
            format!("{:.2}", gang as f64 / 1e6),
            format!("{:.2}", bubble as f64 / 1e6),
            format!("{:.2}x", gang as f64 / bubble as f64),
        ]);
    }
    println!("{}", t.render());
    println!("expected shape: bubble gangs ≈ 2x (they fill all 8 CPUs with two 4-thread gangs;\nOusterhout leaves 4 CPUs idle per slice).");
}
