//! §2.2's contention claim, measured: "a unique thread list for the
//! whole machine is a bottleneck, particularly when the machine has
//! many processors" (Dandamudi & Cheng). We hammer a single global
//! RunList vs per-CPU lists from N OS threads and report throughput.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use bubbles::rq::RunList;
use bubbles::task::TaskId;
use bubbles::topology::LevelId;
use bubbles::util::fmt::Table;

/// Ops/sec with `threads` workers over `lists` (each worker uses
/// list[worker % lists]).
fn throughput(threads: usize, lists: usize, dur_ms: u64) -> f64 {
    let lists: Arc<Vec<RunList>> =
        Arc::new((0..lists).map(|i| RunList::new(LevelId(i))).collect());
    let stop = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();
    for w in 0..threads {
        let lists = lists.clone();
        let stop = stop.clone();
        joins.push(std::thread::spawn(move || {
            let l = &lists[w % lists.len()];
            let mut ops = 0u64;
            while !stop.load(Ordering::Relaxed) {
                l.push(TaskId(w), 1);
                let _ = l.pop_max();
                ops += 2;
            }
            ops
        }));
    }
    std::thread::sleep(std::time::Duration::from_millis(dur_ms));
    stop.store(true, Ordering::Relaxed);
    let total: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
    total as f64 / (dur_ms as f64 / 1e3)
}

fn main() {
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let dur = if fast { 50 } else { 300 };
    println!("runqueue contention: single global list vs per-CPU lists\n");
    let mut t = Table::new(&["threads", "global Mops/s", "per-cpu Mops/s", "hierarchy win"]);
    for threads in [1usize, 2, 4, 8] {
        let global = throughput(threads, 1, dur);
        let percpu = throughput(threads, threads, dur);
        t.row(&[
            threads.to_string(),
            format!("{:.2}", global / 1e6),
            format!("{:.2}", percpu / 1e6),
            format!("{:.2}x", percpu / global),
        ]);
    }
    println!("{}", t.render());
    println!("expected shape: the win grows with the thread count (§2.2).");
}
