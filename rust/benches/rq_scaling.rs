//! Runqueue scaling, three measurements:
//!
//! 1. **Contention** — §2.2's claim, measured: "a unique thread list
//!    for the whole machine is a bottleneck, particularly when the
//!    machine has many processors" (Dandamudi & Cheng). We hammer a
//!    single global list vs per-CPU lists from N OS threads.
//! 2. **Pick path** — the paper's two-pass search (pass-1 lock-free
//!    hint scan over a covering chain + pass-2 locked pop) under
//!    contention on a numa-4x4 machine.
//! 3. **Contended pick/steal** (the gated matrix) — N OS workers, each
//!    the owner of its leaf list, running the scheduler's hot mix:
//!    push-own + pick-own with a steal probe at a neighbour every 4th
//!    round. Four legs per (shape, threads) cell: `locked` = plain
//!    bucket `RunList`, `lockless` = two-tier `RunList` with the
//!    Chase-Lev fast lane in front, `trace-off` = lockless with the
//!    sharded event trace compiled into the loop but disabled (the
//!    production hot-path shape — one atomic load + branch per op),
//!    `trace-on` = lockless with the trace recording every round. The
//!    lockless/locked throughput ratio is the PR-6 acceptance number
//!    (≥1.5× at 8 threads on numa-4x4); the trace-off/lockless ratio
//!    is the PR-7 acceptance number (disabled tracing must cost <5%
//!    ns/op, asserted in gate mode against the same-run lockless leg).
//!
//! Results are printed as tables *and* written machine-readably to
//! `BENCH_rq.json` (schema 2 — see `benches/BENCH_SCHEMA.md`), with
//! provenance: git revision, a FNV-1a hash of the bench configuration,
//! and the run mode, so a history of committed baselines is comparable
//! run-over-run.
//!
//! **Gate mode** (`BENCH_GATE=1`): before overwriting `BENCH_rq.json`,
//! a baseline file is read and every contended leg is compared via
//! `bubbles::bench::gate` (±25% ns/op threshold). The baseline path
//! defaults to the committed `BENCH_rq.json` and is overridden with
//! `BENCH_BASELINE=<path>` — CI records a baseline on the same runner
//! first, then gates subsequent runs against it, so the comparison is
//! matched-leg and same-machine rather than cross-runner. A regressed
//! leg exits nonzero *after* writing the fresh file, so CI both fails
//! and uploads the evidence. An empty/absent baseline makes the run
//! record-only. `BENCH_INJECT_REGRESSION=<f>` multiplies the measured
//! contended ns/op by `f` — CI uses it to prove the armed gate
//! actually fails on a planted 2× regression.
//!
//! Acceptance shape: hierarchy win grows with threads; pick-path ns/op
//! stays flat-ish as PRs land; lockless beats locked under contention.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use bubbles::bench::gate;
use bubbles::rq::{owner, RunList, FAST_LANE_PRIO};
use bubbles::task::TaskId;
use bubbles::topology::{CpuId, LevelId, Topology};
use bubbles::trace::{Event, Trace};
use bubbles::util::fmt::Table;

// ---------------------------------------------------------- contention

/// Ops/sec with `threads` workers over `lists` (each worker uses
/// list[worker % lists]).
fn throughput(threads: usize, lists: usize, dur_ms: u64) -> f64 {
    let lists: Arc<Vec<RunList>> =
        Arc::new((0..lists).map(|i| RunList::new(LevelId(i))).collect());
    let stop = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();
    for w in 0..threads {
        let lists = lists.clone();
        let stop = stop.clone();
        joins.push(std::thread::spawn(move || {
            let l = &lists[w % lists.len()];
            let mut ops = 0u64;
            while !stop.load(Ordering::Relaxed) {
                l.push(TaskId(w), 1);
                let _ = l.pop_max();
                ops += 2;
            }
            ops
        }));
    }
    std::thread::sleep(std::time::Duration::from_millis(dur_ms));
    stop.store(true, Ordering::Relaxed);
    let total: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
    total as f64 / (dur_ms as f64 / 1e3)
}

// ----------------------------------------------------------- pick path

/// Average ns per pick cycle (push + pass-1 hint scan over the CPU's
/// covering chain + pass-2 locked pop) with `threads` workers hammering
/// a shared numa-4x4 list hierarchy. Workers map onto CPUs round-robin,
/// so ≥16 threads means every chain is contended and the shared node /
/// root lists see cross-CPU traffic.
fn pick_path_ns(topo: &Topology, threads: usize, dur_ms: u64) -> f64 {
    let lists: Arc<Vec<RunList>> =
        Arc::new((0..topo.n_components()).map(|i| RunList::new(LevelId(i))).collect());
    let stop = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();
    for w in 0..threads {
        let lists = lists.clone();
        let stop = stop.clone();
        let cpu = CpuId(w % topo.n_cpus());
        let chain: Vec<usize> = topo.covering(cpu).iter().map(|l| l.0).collect();
        joins.push(std::thread::spawn(move || {
            let leaf = chain[0];
            let root = *chain.last().unwrap();
            let mut ops = 0u64;
            while !stop.load(Ordering::Relaxed) {
                // Mostly-local traffic plus a slice of global traffic,
                // like a yield loop with occasional machine-wide work.
                let target = if ops % 8 == 0 { root } else { leaf };
                lists[target].push(TaskId(w), 2);
                // Pass 1: scan the covering chain's hints, pick best.
                let mut best: Option<usize> = None;
                let mut best_p = i32::MIN;
                for &l in &chain {
                    let p = lists[l].peek_max();
                    if p > best_p {
                        best_p = p;
                        best = Some(l);
                    }
                }
                // Pass 2: locked pop (retry once on a lost race).
                if let Some(l) = best {
                    if lists[l].pop_max().is_none() {
                        let _ = lists[leaf].pop_max();
                    }
                }
                ops += 1;
            }
            ops
        }));
    }
    std::thread::sleep(std::time::Duration::from_millis(dur_ms));
    stop.store(true, Ordering::Relaxed);
    let total: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
    (dur_ms as f64 * 1e6) * threads as f64 / total.max(1) as f64
}

// ------------------------------------------------- contended pick/steal

/// The gated benchmark: `threads` OS workers over one `RunList` per
/// CPU, each worker the *owner* of the list of CPU `w % n_cpus`. Hot
/// mix per round: push-own at thread priority + pick-own, and every 4th
/// round a steal probe at the neighbouring CPU's list — the same
/// operations `ops::enqueue` / `pick` / `steal_closest` issue, minus
/// the policy glue. `lockless` legs build the lists with a fast lane
/// and register the worker as its CPU's owner; `locked` legs use the
/// plain bucket list (every op takes the mutex). Returns (ns/op,
/// Mops/s).
/// Tracing flavour of a contended leg: no trace object at all, trace
/// present but disabled (the production hot-path shape), or recording.
#[derive(Clone, Copy, PartialEq)]
enum TraceLeg {
    None,
    Off,
    On,
}

fn contended_ns(
    topo: &Topology,
    threads: usize,
    lockless: bool,
    tl: TraceLeg,
    dur_ms: u64,
) -> (f64, f64) {
    let n_cpus = topo.n_cpus();
    let lists: Arc<Vec<RunList>> = Arc::new(
        (0..n_cpus)
            .map(|i| {
                if lockless {
                    RunList::with_fast_lane(LevelId(i), CpuId(i))
                } else {
                    RunList::new(LevelId(i))
                }
            })
            .collect(),
    );
    let trace = match tl {
        TraceLeg::None => None,
        TraceLeg::Off => Some(Arc::new(Trace::for_cpus(n_cpus, 1 << 12))),
        TraceLeg::On => {
            let t = Arc::new(Trace::for_cpus(n_cpus, 1 << 12));
            t.set_enabled(true);
            Some(t)
        }
    };
    let stop = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();
    for w in 0..threads {
        let lists = lists.clone();
        let stop = stop.clone();
        let trace = trace.clone();
        let cpu = w % n_cpus;
        joins.push(std::thread::spawn(move || {
            owner::set_current_cpu(Some(CpuId(cpu)));
            let own = &lists[cpu];
            let neighbour = &lists[(cpu + 1) % lists.len()];
            let mut ops = 0u64;
            while !stop.load(Ordering::Relaxed) {
                own.push(TaskId(w), FAST_LANE_PRIO);
                let _ = own.pop_max();
                ops += 2;
                // The production emit shape: enabled() check first, so
                // the disabled leg pays one atomic load + branch and
                // never constructs the event.
                if let Some(t) = &trace {
                    if t.enabled() {
                        t.emit(ops, Event::Dispatch { task: TaskId(w), cpu: CpuId(cpu) });
                    }
                }
                if ops % 8 == 0 {
                    // Steal probe: thief-side pop on a list this worker
                    // does not own.
                    let _ = neighbour.pop_max();
                    ops += 1;
                }
            }
            owner::set_current_cpu(None);
            ops
        }));
    }
    std::thread::sleep(std::time::Duration::from_millis(dur_ms));
    stop.store(true, Ordering::Relaxed);
    let total: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
    let ns_op = (dur_ms as f64 * 1e6) * threads as f64 / total.max(1) as f64;
    let mops = total as f64 / (dur_ms as f64 * 1e3);
    (ns_op, mops)
}

// ----------------------------------------------------------- provenance
//
// The FNV config hash and git revision come from the shared gate
// module (`gate::fnv1a` / `gate::git_rev`) so this bench stamps its
// artifact exactly like the experiment harness does.

// ---------------------------------------------------------------- main

fn json_escape_free(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.2}")
    } else {
        "null".to_string()
    }
}

const CONTENDED_THREADS: [usize; 3] = [2, 4, 8];

fn main() {
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let gated = std::env::var("BENCH_GATE").map(|v| v == "1").unwrap_or(false);
    let inject: f64 = std::env::var("BENCH_INJECT_REGRESSION")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    let dur = if fast { 50 } else { 300 };

    // Read the baseline *before* this run overwrites BENCH_rq.json.
    // BENCH_BASELINE points at a recorded same-runner baseline (how CI
    // arms the gate); the default is the committed file.
    let baseline_path =
        std::env::var("BENCH_BASELINE").unwrap_or_else(|_| "BENCH_rq.json".to_string());
    let baseline = if gated { std::fs::read_to_string(&baseline_path).ok() } else { None };

    println!("runqueue contention: single global list vs per-CPU lists\n");
    let mut contention_rows = Vec::new();
    let mut t = Table::new(&["threads", "global Mops/s", "per-cpu Mops/s", "hierarchy win"]);
    for threads in [1usize, 2, 4, 8] {
        let global = throughput(threads, 1, dur);
        let percpu = throughput(threads, threads, dur);
        t.row(&[
            threads.to_string(),
            format!("{:.2}", global / 1e6),
            format!("{:.2}", percpu / 1e6),
            format!("{:.2}x", percpu / global),
        ]);
        contention_rows.push(format!(
            "{{\"threads\":{threads},\"global_mops\":{},\"percpu_mops\":{}}}",
            json_escape_free(global / 1e6),
            json_escape_free(percpu / 1e6)
        ));
    }
    println!("{}", t.render());
    println!("expected shape: the win grows with the thread count (§2.2).\n");

    println!("pick path (two-pass over numa-4x4 chains): bucket-array RunList\n");
    let numa = Topology::numa(4, 4);
    let mut pick_rows = Vec::new();
    let mut t2 = Table::new(&["threads", "bucket ns/op"]);
    for threads in [1usize, 4, 16, 32] {
        let bucket = pick_path_ns(&numa, threads, dur);
        t2.row(&[threads.to_string(), format!("{bucket:.1}")]);
        pick_rows.push(format!(
            "{{\"threads\":{threads},\"bucket_ns\":{}}}",
            json_escape_free(bucket)
        ));
    }
    println!("{}", t2.render());
    println!("acceptance shape: ns/op comparable to the BENCH_rq.json history.\n");

    println!("contended pick/steal: locked buckets vs lock-free fast lane\n");
    if inject != 1.0 {
        println!("(BENCH_INJECT_REGRESSION={inject}: reported ns/op scaled accordingly)\n");
    }
    let shapes = [Topology::smp(4), numa];
    const LEGS: [(&str, bool, TraceLeg); 4] = [
        ("locked", false, TraceLeg::None),
        ("lockless", true, TraceLeg::None),
        ("trace-off", true, TraceLeg::Off),
        ("trace-on", true, TraceLeg::On),
    ];
    let mut contended_rows = Vec::new();
    let mut current_legs = Vec::new();
    let mut trace_tax_ratios = Vec::new();
    let mut t3 = Table::new(&[
        "shape",
        "threads",
        "locked ns/op",
        "lockless ns/op",
        "trace-off ns/op",
        "trace-on ns/op",
        "lockless win",
        "trace tax",
    ]);
    for topo in &shapes {
        for threads in CONTENDED_THREADS {
            let mut cell = [0.0f64; LEGS.len()];
            for (i, &(leg, lockless, tl)) in LEGS.iter().enumerate() {
                let (mut ns_op, mut mops) = contended_ns(topo, threads, lockless, tl, dur);
                ns_op *= inject;
                mops /= inject;
                cell[i] = ns_op;
                contended_rows.push(format!(
                    "{{\"shape\":\"{}\",\"threads\":{threads},\"leg\":\"{leg}\",\"ns_op\":{},\"mops\":{}}}",
                    topo.name(),
                    json_escape_free(ns_op),
                    json_escape_free(mops)
                ));
                current_legs.push(gate::LegResult {
                    shape: topo.name().to_string(),
                    threads,
                    leg: leg.to_string(),
                    ns_op,
                    mops,
                });
            }
            // Disabled-tracing overhead vs the same-run untraced leg —
            // same machine, same moment, so runner noise cancels.
            trace_tax_ratios.push(cell[2] / cell[1].max(f64::MIN_POSITIVE));
            t3.row(&[
                topo.name().to_string(),
                threads.to_string(),
                format!("{:.1}", cell[0]),
                format!("{:.1}", cell[1]),
                format!("{:.1}", cell[2]),
                format!("{:.1}", cell[3]),
                format!("{:.2}x", cell[0] / cell[1].max(f64::MIN_POSITIVE)),
                format!("{:.3}x", cell[2] / cell[1].max(f64::MIN_POSITIVE)),
            ]);
        }
    }
    println!("{}", t3.render());
    println!("acceptance: lockless ≥1.5x locked throughput at 8 threads on numa-4x4.");
    let trace_tax =
        trace_tax_ratios.iter().sum::<f64>() / trace_tax_ratios.len().max(1) as f64;
    println!(
        "tracing overhead (disabled): mean trace-off/lockless ns/op ratio {trace_tax:.3}x \
         across {} cells (budget 1.05x)",
        trace_tax_ratios.len()
    );

    let config = format!(
        "shapes=smp-4,numa-4x4;threads={CONTENDED_THREADS:?};legs=locked,lockless,trace-off,trace-on;dur_ms={dur}"
    );
    let json = format!(
        "{{\n  \"bench\": \"rq_scaling\",\n  \"schema\": 2,\n  \"mode\": \"{}\",\n  \"git_rev\": \"{}\",\n  \"config_hash\": \"{:016x}\",\n  \"machine\": \"{}\",\n  \"contention\": [{}],\n  \"pick_path\": [{}],\n  \"contended\": [{}]\n}}\n",
        if fast { "fast" } else { "full" },
        gate::git_rev(),
        gate::fnv1a(&config),
        shapes[1].name(),
        contention_rows.join(","),
        pick_rows.join(","),
        contended_rows.join(",\n")
    );
    match std::fs::write("BENCH_rq.json", &json) {
        Ok(()) => println!("\nwrote BENCH_rq.json"),
        Err(e) => eprintln!("\ncould not write BENCH_rq.json: {e}"),
    }

    if gated {
        // Same-run overhead assertion: disabled tracing must stay
        // under +5% ns/op vs the untraced lockless leg. Compared
        // within one run (not against the committed baseline), so the
        // check is immune to runner-to-runner drift.
        if trace_tax > 1.05 {
            eprintln!(
                "bench gate: disabled tracing costs {:.1}% ns/op on the contended \
                 lockless legs (budget 5%)",
                (trace_tax - 1.0) * 100.0
            );
            std::process::exit(3);
        }
        let base_legs = baseline.as_deref().map(gate::parse_legs).unwrap_or_default();
        if base_legs.is_empty() {
            println!(
                "\nbench gate: no contended legs in baseline `{baseline_path}` — record-only run."
            );
            return;
        }
        let report = gate::compare(&base_legs, &current_legs, gate::DEFAULT_THRESHOLD);
        println!(
            "\nbench gate vs baseline `{baseline_path}` (threshold +{:.0}%):",
            (gate::DEFAULT_THRESHOLD - 1.0) * 100.0
        );
        print!("{}", report.render());
        if !report.passed() {
            eprintln!("bench gate: {} leg(s) regressed past threshold", report.regressions().len());
            std::process::exit(2);
        }
        println!("bench gate: passed ({} legs compared)", report.deltas.len());
    }
}
