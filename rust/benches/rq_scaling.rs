//! Runqueue scaling, two measurements:
//!
//! 1. **Contention** — §2.2's claim, measured: "a unique thread list
//!    for the whole machine is a bottleneck, particularly when the
//!    machine has many processors" (Dandamudi & Cheng). We hammer a
//!    single global list vs per-CPU lists from N OS threads.
//! 2. **Pick path** — the paper's two-pass search (pass-1 lock-free
//!    hint scan over a covering chain + pass-2 locked pop) under
//!    contention on a numa-4x4 machine.
//!
//! Results are printed as tables *and* written machine-readably to
//! `BENCH_rq.json`, so the perf trajectory is tracked across PRs. The
//! legacy `BTreeRunList` comparison leg is gone (PR 5): the bucket
//! layout won across several PRs of `BENCH_rq.json` history, so the
//! pick path is now tracked in absolute ns/op.
//! Acceptance shape: hierarchy win grows with threads; pick-path ns/op
//! stays flat-ish as PRs land.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use bubbles::rq::RunList;
use bubbles::task::TaskId;
use bubbles::topology::{CpuId, LevelId, Topology};
use bubbles::util::fmt::Table;

// ---------------------------------------------------------- contention

/// Ops/sec with `threads` workers over `lists` (each worker uses
/// list[worker % lists]).
fn throughput(threads: usize, lists: usize, dur_ms: u64) -> f64 {
    let lists: Arc<Vec<RunList>> =
        Arc::new((0..lists).map(|i| RunList::new(LevelId(i))).collect());
    let stop = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();
    for w in 0..threads {
        let lists = lists.clone();
        let stop = stop.clone();
        joins.push(std::thread::spawn(move || {
            let l = &lists[w % lists.len()];
            let mut ops = 0u64;
            while !stop.load(Ordering::Relaxed) {
                l.push(TaskId(w), 1);
                let _ = l.pop_max();
                ops += 2;
            }
            ops
        }));
    }
    std::thread::sleep(std::time::Duration::from_millis(dur_ms));
    stop.store(true, Ordering::Relaxed);
    let total: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
    total as f64 / (dur_ms as f64 / 1e3)
}

// ----------------------------------------------------------- pick path

/// Average ns per pick cycle (push + pass-1 hint scan over the CPU's
/// covering chain + pass-2 locked pop) with `threads` workers hammering
/// a shared numa-4x4 list hierarchy. Workers map onto CPUs round-robin,
/// so ≥16 threads means every chain is contended and the shared node /
/// root lists see cross-CPU traffic.
fn pick_path_ns(topo: &Topology, threads: usize, dur_ms: u64) -> f64 {
    let lists: Arc<Vec<RunList>> =
        Arc::new((0..topo.n_components()).map(|i| RunList::new(LevelId(i))).collect());
    let stop = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();
    for w in 0..threads {
        let lists = lists.clone();
        let stop = stop.clone();
        let cpu = CpuId(w % topo.n_cpus());
        let chain: Vec<usize> = topo.covering(cpu).iter().map(|l| l.0).collect();
        joins.push(std::thread::spawn(move || {
            let leaf = chain[0];
            let root = *chain.last().unwrap();
            let mut ops = 0u64;
            while !stop.load(Ordering::Relaxed) {
                // Mostly-local traffic plus a slice of global traffic,
                // like a yield loop with occasional machine-wide work.
                let target = if ops % 8 == 0 { root } else { leaf };
                lists[target].push(TaskId(w), 2);
                // Pass 1: scan the covering chain's hints, pick best.
                let mut best: Option<usize> = None;
                let mut best_p = i32::MIN;
                for &l in &chain {
                    let p = lists[l].peek_max();
                    if p > best_p {
                        best_p = p;
                        best = Some(l);
                    }
                }
                // Pass 2: locked pop (retry once on a lost race).
                if let Some(l) = best {
                    if lists[l].pop_max().is_none() {
                        let _ = lists[leaf].pop_max();
                    }
                }
                ops += 1;
            }
            ops
        }));
    }
    std::thread::sleep(std::time::Duration::from_millis(dur_ms));
    stop.store(true, Ordering::Relaxed);
    let total: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
    (dur_ms as f64 * 1e6) * threads as f64 / total.max(1) as f64
}

// ---------------------------------------------------------------- main

fn json_escape_free(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.2}")
    } else {
        "null".to_string()
    }
}

fn main() {
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let dur = if fast { 50 } else { 300 };

    println!("runqueue contention: single global list vs per-CPU lists\n");
    let mut contention_rows = Vec::new();
    let mut t = Table::new(&["threads", "global Mops/s", "per-cpu Mops/s", "hierarchy win"]);
    for threads in [1usize, 2, 4, 8] {
        let global = throughput(threads, 1, dur);
        let percpu = throughput(threads, threads, dur);
        t.row(&[
            threads.to_string(),
            format!("{:.2}", global / 1e6),
            format!("{:.2}", percpu / 1e6),
            format!("{:.2}x", percpu / global),
        ]);
        contention_rows.push(format!(
            "{{\"threads\":{threads},\"global_mops\":{},\"percpu_mops\":{}}}",
            json_escape_free(global / 1e6),
            json_escape_free(percpu / 1e6)
        ));
    }
    println!("{}", t.render());
    println!("expected shape: the win grows with the thread count (§2.2).\n");

    println!("pick path (two-pass over numa-4x4 chains): bucket-array RunList\n");
    let topo = Topology::numa(4, 4);
    let mut pick_rows = Vec::new();
    let mut t2 = Table::new(&["threads", "bucket ns/op"]);
    for threads in [1usize, 4, 16, 32] {
        let bucket = pick_path_ns(&topo, threads, dur);
        t2.row(&[threads.to_string(), format!("{bucket:.1}")]);
        pick_rows.push(format!(
            "{{\"threads\":{threads},\"bucket_ns\":{}}}",
            json_escape_free(bucket)
        ));
    }
    println!("{}", t2.render());
    println!("acceptance shape: ns/op comparable to the BENCH_rq.json history.");

    let json = format!(
        "{{\n  \"bench\": \"rq_scaling\",\n  \"mode\": \"{}\",\n  \"machine\": \"{}\",\n  \"contention\": [{}],\n  \"pick_path\": [{}]\n}}\n",
        if fast { "fast" } else { "full" },
        topo.name(),
        contention_rows.join(","),
        pick_rows.join(",")
    );
    match std::fs::write("BENCH_rq.json", &json) {
        Ok(()) => println!("\nwrote BENCH_rq.json"),
        Err(e) => eprintln!("\ncould not write BENCH_rq.json: {e}"),
    }
}
