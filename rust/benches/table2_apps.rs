//! Table 2 — conduction & advection under Sequential / Simple / Bound /
//! Bubbles on the NovaScale stand-in (numa-4x4, NUMA factor 3).
//! BENCH_FULL=1 runs the full cycle counts.

use bubbles::experiments::table2;
use bubbles::topology::Topology;

fn main() {
    let full = std::env::var("BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let scale = if full { 1.0 } else { 0.25 };
    let topo = Topology::numa(4, 4);
    let t2 = table2::run(&topo, scale);
    println!("Table 2 on `{}` (scale {scale})", topo.name());
    println!("(paper: Simple 10.58/9.11, Bound 15.82/12.40, Bubbles 15.80/12.40)\n");
    println!("{}", t2.render());
    let b = t2.row("Bound");
    let u = t2.row("Bubbles");
    let s = t2.row("Simple");
    println!(
        "shape: bubbles/bound speedup gap = {:.1}% (paper 0.1%), bound/simple = {:.2}x (paper 1.50x)",
        100.0 * (b.conduction_speedup - u.conduction_speedup).abs() / b.conduction_speedup,
        b.conduction_speedup / s.conduction_speedup,
    );
}
